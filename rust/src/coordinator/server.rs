//! The coordinator server: admission/coalescing queue + preprocessing
//! workers + an executor thread.
//!
//! Ownership model: `xla::PjRtClient` is not `Sync`, so exactly one executor
//! thread owns the [`Runtime`]; preprocessing (BSB build + bucket planning,
//! pure CPU) happens on a small worker pool in front of it.  This mirrors
//! the paper's split between per-graph preprocessing ("negligible overhead,
//! done once per input graph") and kernel execution.
//!
//! Request path (all std threads + mpsc; tokio is unavailable offline):
//!
//! 1. **admission** — `submit` pushes onto a *bounded* ingress queue;
//!    when the queue is full the caller blocks (backpressure, never
//!    drops).  The batcher → worker and worker → executor queues are
//!    bounded too (same `queue_capacity`), so overload propagates back to
//!    `submit` instead of accumulating merged feature buffers in memory;
//! 2. **coalescing** — a single batcher thread first resolves
//!    [`Backend::Auto`] through the adaptive planner
//!    ([`crate::planner`]; profile → cost model → cheapest feasible
//!    backend), then groups compatible pending requests (same
//!    d/dv/heads/scale and *resolved* backend) by the size/deadline policy
//!    (`max_batch_nodes`, `max_batch_delay`) into block-diagonal batches —
//!    the paper's §4.1 batched-graph workload, applied to serving.
//!    Resolving before grouping means auto traffic coalesces with, and
//!    shares cached plans with, explicitly-routed traffic;
//! 3. **preprocessing** — workers merge each batch into one `CsrGraph`
//!    (`graph::batch::batch_graph_refs`), consult the fingerprint-keyed
//!    BSB cache, and build a shared [`Plan`] on the process-wide
//!    [`Engine`];
//! 4. **execution** — the executor runs **one multi-head plan call per
//!    batch** (one `AttentionBatch` over every request's heads; PJRT
//!    artifacts, or the offline host emulation under
//!    [`ExecutorKind::HostEmulation`]) and scatters per-component,
//!    per-head output rows back to each caller's reply channel.
//!
//! Because the block-diagonal adjacency keeps every row's neighbour lanes
//! in the same ascending-column order as a per-graph run, the batched
//! outputs are **bit-identical** to serial per-request execution (pinned by
//! `rust/tests/batching_equivalence.rs`).
//!
//! **Failure model** (DESIGN.md §11): every stage is a panic boundary —
//! a panic in planner resolution, plan preparation, or kernel execution is
//! caught, converted to a structured [`AttnError`], and answered on the
//! request's reply channel; no stage thread dies, no responder is dropped.
//! Prepare/execute failures walk a degradation ladder: retry once,
//! quarantine the failing `(fingerprint, backend)` pair
//! ([`super::recover::Quarantine`]), evict the possibly-poisoned
//! [`DriverCache`] entry, re-resolve over the remaining feasible backends,
//! and — for merged batches — split into singleton execution so one bad
//! request cannot fail its batch-mates.  Requests carrying a
//! [`AttnRequest::deadline`] are shed with
//! [`AttnError::DeadlineExceeded`] at every queueing point once the
//! deadline passes.  The chaos suite (`rust/tests/chaos.rs`) locks all of
//! this under seeded fault injection ([`crate::fault`]).

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::path::PathBuf;
use std::sync::mpsc::{channel, sync_channel, Receiver, Sender, SyncSender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::bsb::{self, incremental, Bsb};
use crate::exec::{offline_manifest, Engine, ExecPolicy};
use crate::fault::{self, FaultSite};
use crate::graph::batch::batch_graph_refs;
use crate::graph::{CsrGraph, GraphDelta};
use crate::kernels::{AttentionBatch, AttnError, Backend, ExecCtx, Plan};
use crate::planner::{self, CostModel, GraphProfile, Planner};
use crate::runtime::{Manifest, Runtime};
use crate::shard::{ShardPolicy, ShardedPlan};
use crate::trace::{self, TraceSite};
use crate::util::sync::lock_unpoisoned;

use super::batcher::{Admitted, BatchPolicy, Coalescer, Flush};
use super::cache::DriverCache;
use super::metrics::Metrics;
use super::recover::Quarantine;
use super::request::{AttnRequest, AttnResponse};

/// How the executor stage actually computes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ExecutorKind {
    /// Dispatch AOT artifacts through PJRT (production; needs
    /// `make artifacts` in `artifacts_dir`).
    Pjrt,
    /// Offline host-kernel emulation: the full coordinator path — batching,
    /// cache, gathers, pipeline, scatters — with no artifacts and no PJRT
    /// (tests, benches, cold CI).  The dense fallback backend is
    /// unavailable in this mode.
    HostEmulation,
}

/// Bucketing configuration used in `HostEmulation` mode (matches the
/// offline test/bench manifests and the planner's profiling ladder).
const OFFLINE_BUCKETS: &[usize] = planner::DEFAULT_BUCKETS;

/// Coordinator configuration.
#[derive(Clone, Debug)]
pub struct CoordinatorConfig {
    pub artifacts_dir: PathBuf,
    /// Preprocessing worker threads.
    pub preprocess_workers: usize,
    /// Bound on the ingress queue before `submit` blocks the caller
    /// (backpressure).
    pub queue_capacity: usize,
    /// Host execution policy shared by preprocessing and the executor.
    pub exec: ExecPolicy,
    /// Kernel dispatch mode (PJRT artifacts vs offline host emulation).
    pub executor: ExecutorKind,
    /// Max requests coalesced into one block-diagonal batch; 1 disables
    /// dynamic batching.
    pub max_batch_requests: usize,
    /// Flush a forming batch once it reaches this many total head-weighted
    /// nodes (Σ n × heads); requests at least this large always run alone.
    pub max_batch_nodes: usize,
    /// Max time the first request of a batch waits for company.
    pub max_batch_delay: Duration,
    /// Prepared-driver (BSB) cache entries; 0 disables the cache.
    pub cache_capacity: usize,
    /// Where the adaptive planner persists its cost-model calibration
    /// (loaded at startup if present, saved at shutdown).  `None` keeps the
    /// refinement in-memory only.
    pub calibration_path: Option<PathBuf>,
    /// Node-count threshold past which a request's graph routes through
    /// the partition-parallel sharded path ([`crate::shard`]) instead of
    /// being planned whole — per-shard plans are cached by shard-local
    /// fingerprint, outputs bit-match the unsharded plan, and coalescing
    /// keeps merged batches under this threshold too.  The shard count is
    /// `ceil(n / max_plan_nodes)` capped at `max_shards`, and the
    /// TCB-balanced partitioner trades node balance for work balance, so
    /// this is a *target* per-shard working set, not a hard per-shard
    /// bound (the cap, plus halo replication, can leave individual shards
    /// above it).  `usize::MAX` (the default) disables the routing.
    pub max_plan_nodes: usize,
    /// Shard-count ceiling for the sharded path; `0` or `1` disables
    /// sharding entirely, so requests above `max_plan_nodes` are refused
    /// with [`AttnError::Unsupported`] (the pre-sharding behaviour made
    /// explicit).
    pub max_shards: usize,
    /// How long the degradation ladder keeps a failing
    /// `(fingerprint, backend)` pair out of service before re-probing it
    /// ([`super::recover::Quarantine`]).  Most failures are transient, so
    /// quarantined backends are re-admitted automatically after this TTL.
    pub quarantine_ttl: Duration,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        CoordinatorConfig {
            artifacts_dir: PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts"),
            preprocess_workers: 2,
            queue_capacity: 64,
            exec: ExecPolicy::auto(),
            executor: ExecutorKind::Pjrt,
            max_batch_requests: 64,
            max_batch_nodes: 16384,
            max_batch_delay: Duration::from_micros(500),
            cache_capacity: 128,
            calibration_path: None,
            max_plan_nodes: usize::MAX,
            max_shards: 16,
            quarantine_ttl: Duration::from_secs(30),
        }
    }
}

impl CoordinatorConfig {
    fn batch_policy(&self) -> BatchPolicy {
        BatchPolicy {
            max_batch_requests: self.max_batch_requests.max(1),
            max_batch_nodes: self.max_batch_nodes.max(1),
            max_batch_delay: self.max_batch_delay,
            max_plan_nodes: self.max_plan_nodes.max(1),
        }
    }

    fn shard_route(&self) -> ShardRoute {
        ShardRoute {
            max_plan_nodes: self.max_plan_nodes.max(1),
            max_shards: self.max_shards,
        }
    }
}

/// The preprocessing workers' view of the sharding knobs.
#[derive(Clone, Copy)]
struct ShardRoute {
    max_plan_nodes: usize,
    max_shards: usize,
}

/// Shared services the preprocessing and executor stages consult: plan
/// building inputs, the BSB cache, the quarantine registry, the planner
/// (for ladder re-resolution) and metrics.  One `Arc` instead of six.
struct Services {
    man: Arc<Manifest>,
    engine: Arc<Engine>,
    cache: Arc<DriverCache>,
    metrics: Arc<Metrics>,
    planner: Arc<Planner>,
    quarantine: Arc<Quarantine>,
    route: ShardRoute,
    /// Compacted BSBs of streaming (delta-updated) graph versions, keyed
    /// by fingerprint — what [`Coordinator::update_graph`] splices clean
    /// row windows from.  Static-topology traffic never touches this.
    bsbs: BsbRegistry,
}

/// A small LRU of `fingerprint → Arc<Bsb>` for graphs under streaming
/// updates.  Separate from [`DriverCache`]: plans don't expose their BSB
/// (sharded plans never had a whole-graph one), and only delta-updated
/// versions need the splice source retained.
struct BsbRegistry {
    capacity: usize,
    inner: Mutex<BsbRegistryInner>,
}

struct BsbRegistryInner {
    map: std::collections::HashMap<u64, (Arc<Bsb>, u64)>,
    tick: u64,
}

impl BsbRegistry {
    fn new(capacity: usize) -> BsbRegistry {
        BsbRegistry {
            capacity,
            inner: Mutex::new(BsbRegistryInner {
                map: std::collections::HashMap::new(),
                tick: 0,
            }),
        }
    }

    fn get(&self, fp: u64) -> Option<Arc<Bsb>> {
        let mut inner = lock_unpoisoned(&self.inner);
        inner.tick += 1;
        let tick = inner.tick;
        let slot = inner.map.get_mut(&fp)?;
        slot.1 = tick;
        Some(slot.0.clone())
    }

    fn insert(&self, fp: u64, bsb: Arc<Bsb>) {
        if self.capacity == 0 {
            return;
        }
        let mut inner = lock_unpoisoned(&self.inner);
        while inner.map.len() >= self.capacity && !inner.map.contains_key(&fp) {
            let oldest = inner
                .map
                .iter()
                .min_by_key(|(_, (_, t))| *t)
                .map(|(&k, _)| k);
            match oldest {
                Some(k) => inner.map.remove(&k),
                None => break,
            };
        }
        inner.tick += 1;
        let tick = inner.tick;
        inner.map.insert(fp, (bsb, tick));
    }

    fn remove(&self, fp: u64) {
        lock_unpoisoned(&self.inner).map.remove(&fp);
    }
}

/// One coalesced unit of work travelling batcher → preprocessing.
struct Job {
    entries: Vec<Admitted>,
}

/// One response route of a prepared batch.  Carries the request's graph so
/// the executor-side degradation ladder can re-plan this member alone if
/// the merged batch fails.
struct Entry {
    id: u64,
    reply: Sender<AttnResponse>,
    arrived: Instant,
    /// Absolute deadline (submit time + `AttnRequest::deadline`).
    expires: Option<Instant>,
    graph: CsrGraph,
    /// Tracing span id (0 = untraced), threaded through to the response.
    span: u64,
}

impl Entry {
    fn expired(&self, now: Instant) -> bool {
        self.expires.map_or(false, |t| t <= now)
    }
}

/// Refinement payload for a batch whose backend the planner chose: the
/// executor pairs these cost cells with the measured execute time and
/// feeds the sample back into the cost model.
struct TuneInfo {
    backend: Backend,
    cells: f64,
}

/// A preprocessed batch waiting for the executor: the merged head-major
/// problem plus per-component scatter routes.
struct PreparedBatch {
    entries: Vec<Entry>,
    /// Component row offsets into the merged problem (len = entries + 1).
    offsets: Vec<u32>,
    n_total: usize,
    d: usize,
    dv: usize,
    heads: usize,
    scale: f32,
    q: Vec<f32>,
    k: Vec<f32>,
    v: Vec<f32>,
    plan: std::result::Result<Arc<Plan>, AttnError>,
    /// The backend the plan was actually prepared on — the requested
    /// backend unless the prepare-time ladder degraded it.  Execute-time
    /// quarantine and the response's `backend` field key on this.
    backend: Backend,
    /// Fingerprint of the (merged) graph the plan was built for.
    fp: u64,
    preprocess_s: f64,
    /// Present iff any member arrived as `Backend::Auto` *and* the plan
    /// was prepared on the backend the cells were priced for (a degraded
    /// batch must not feed a mismatched sample into the cost model).
    tune: Option<TuneInfo>,
}

/// Handle to a running coordinator.  Each request travels with its
/// submit-time stamp so reported latency includes time spent queued in
/// (or blocked on) the bounded ingress — the overload regime is exactly
/// when that time matters.
///
/// The handle is `Sync`: clients on many threads may `submit` through one
/// shared (`Arc`ed) coordinator while another thread calls `shutdown` —
/// a submit racing the teardown either lands before the ingress closes
/// (and is answered: shutdown drains every accepted request) or observes
/// [`AttnError::QueueClosed`]; its responder is never silently dropped.
pub struct Coordinator {
    /// `None` once `shutdown` has closed admission.
    ingress: Mutex<Option<SyncSender<(AttnRequest, Instant)>>>,
    metrics: Arc<Metrics>,
    planner: Arc<Planner>,
    calibration_path: Option<PathBuf>,
    stages: Mutex<Stages>,
    /// Shared with the stage threads; [`Coordinator::update_graph`] uses it
    /// to rebuild and atomically swap cached plans out of band.
    services: Arc<Services>,
}

/// What [`Coordinator::update_graph`] did: the version edge, effective
/// edit counts, the incremental-rebuild split, and which backends' plans
/// were swapped to the patched fingerprint.
#[derive(Clone, Debug)]
pub struct UpdateReport {
    /// Fingerprint of the base version (now evicted, unless the delta was
    /// a no-op and the fingerprints coincide).
    pub old_fp: u64,
    /// Fingerprint of the patched version (now cache-hot).
    pub new_fp: u64,
    /// The patched graph — what subsequent requests should carry.
    pub patched: Arc<CsrGraph>,
    /// Edges actually added (no-op inserts excluded).
    pub inserted: usize,
    /// Edges actually dropped (no-op removes excluded).
    pub removed: usize,
    /// Row windows the delta dirtied (recomputed by the rebuild).
    pub dirty_rws: usize,
    /// Row windows spliced verbatim from the previous version's BSB
    /// (zero when the update fell back to a full rebuild).
    pub spliced_rws: usize,
    /// Whether the BSB was rebuilt from scratch (first update of this
    /// graph, incompatible previous version, or a caught panic in the
    /// incremental path).
    pub full_rebuild: bool,
    /// Backends whose plans were rebuilt and swapped, in deterministic
    /// (name) order.
    pub plans_swapped: Vec<Backend>,
}

/// The coordinator's stage threads, joined (once) at shutdown.
struct Stages {
    batcher: Option<JoinHandle<()>>,
    workers: Vec<JoinHandle<()>>,
    executor: Option<JoinHandle<()>>,
}

impl Coordinator {
    /// Start the batcher, worker pool, and executor.  The executor compiles
    /// executables lazily; call [`Runtime::warmup`] patterns via a first
    /// dummy request if cold-start latency matters.
    pub fn start(cfg: CoordinatorConfig) -> Result<Coordinator> {
        // Validate the manifest eagerly so startup fails fast.  The PJRT
        // client itself is constructed *inside* the executor thread: the xla
        // client is reference-counted and not Send.
        let manifest = Arc::new(match cfg.executor {
            ExecutorKind::Pjrt => Manifest::load(&cfg.artifacts_dir)
                .context("coordinator startup: loading artifacts")?,
            ExecutorKind::HostEmulation => offline_manifest(8, OFFLINE_BUCKETS, 128),
        });

        let metrics = Arc::new(Metrics::new());
        // One engine for the whole coordinator: preprocessing shards BSB
        // builds across its pool, the executor pipelines calls through it,
        // and its buffer arena recycles staging memory across requests.
        let engine = Arc::new(Engine::new(cfg.exec));
        let cache = Arc::new(DriverCache::new(cfg.cache_capacity));

        // The adaptive planner behind `Backend::Auto`.  A persisted
        // calibration (if any) seeds the cost model; an unreadable or
        // corrupt file degrades to factory constants rather than failing
        // startup.  The dense fallback is only a candidate when the loaded
        // manifest actually carries compiled dense executables (host
        // emulation cannot run it, and fast-mode artifact builds may omit
        // it) — the same gate `Backend::resolve_for` applies standalone.
        let model = match &cfg.calibration_path {
            Some(path) if path.exists() => CostModel::load(path)
                .map_err(|e| eprintln!("planner: ignoring calibration: {e:#}"))
                .unwrap_or_default(),
            _ => CostModel::default(),
        };
        let dense_available = cfg.executor == ExecutorKind::Pjrt
            && manifest.entries.keys().any(|k| k.starts_with("dense_n"));
        let planner = Arc::new(if dense_available {
            Planner::new(model)
        } else {
            Planner::offline(model)
        });

        let services = Arc::new(Services {
            man: manifest,
            engine,
            cache,
            metrics: metrics.clone(),
            planner: planner.clone(),
            quarantine: Arc::new(Quarantine::new(cfg.quarantine_ttl)),
            route: cfg.shard_route(),
            bsbs: BsbRegistry::new(cfg.cache_capacity.max(1)),
        });

        // Bounded queues end to end: submit blocks (never drops) once the
        // ingress fills, and the batcher/worker stages block rather than
        // buffer unbounded merged feature payloads, so sustained overload
        // surfaces as submit-side backpressure instead of memory growth.
        let bound = cfg.queue_capacity.max(1);
        let (ingress_tx, ingress_rx) = sync_channel::<(AttnRequest, Instant)>(bound);
        let (job_tx, job_rx) = sync_channel::<Job>(bound);
        let (prep_tx, prep_rx) = sync_channel::<PreparedBatch>(bound);

        // Stage 1: the single coalescing thread — which also resolves
        // `Backend::Auto` so coalescing groups and the plan cache both key
        // on the *resolved* backend.
        let policy = cfg.batch_policy();
        let pl = planner.clone();
        let met = metrics.clone();
        let batcher = std::thread::spawn(move || {
            batcher_loop(ingress_rx, job_tx, policy, pl, met)
        });

        // Stage 2: preprocessing workers share the job queue.
        let job_rx = Arc::new(Mutex::new(job_rx));
        let mut workers = Vec::new();
        for _ in 0..cfg.preprocess_workers.max(1) {
            let rx = job_rx.clone();
            let tx = prep_tx.clone();
            let svc = services.clone();
            workers.push(std::thread::spawn(move || {
                preprocess_worker(rx, tx, svc)
            }));
        }
        drop(prep_tx);

        // Stage 3: the executor.  In PJRT mode it constructs and owns the
        // runtime on its own thread; startup errors are reported back
        // before `start` returns.  Host emulation needs no runtime.
        let dir = cfg.artifacts_dir.clone();
        let kind = cfg.executor;
        let svc = services.clone();
        let (ready_tx, ready_rx) = channel::<std::result::Result<(), String>>();
        let executor = std::thread::spawn(move || {
            let backend = match kind {
                ExecutorKind::Pjrt => match Runtime::new(&dir) {
                    Ok(rt) => {
                        let _ = ready_tx.send(Ok(()));
                        ExecBackend::Pjrt(rt)
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(format!("{e:#}")));
                        return;
                    }
                },
                ExecutorKind::HostEmulation => {
                    let _ = ready_tx.send(Ok(()));
                    ExecBackend::Host
                }
            };
            executor_loop(backend, prep_rx, svc)
        });
        ready_rx
            .recv()
            .map_err(|_| anyhow::anyhow!("executor thread died at startup"))?
            .map_err(|e| anyhow::anyhow!("executor startup: {e}"))?;

        Ok(Coordinator {
            ingress: Mutex::new(Some(ingress_tx)),
            metrics,
            planner,
            calibration_path: cfg.calibration_path.clone(),
            stages: Mutex::new(Stages {
                batcher: Some(batcher),
                workers,
                executor: Some(executor),
            }),
            services,
        })
    }

    /// Submit a request.  Blocks while the ingress queue is at
    /// `queue_capacity` (backpressure); the reply arrives on `req.reply`.
    /// Requests may carry [`Backend::Auto`]: the batcher resolves them
    /// through the adaptive planner before coalescing, and the measured
    /// latency of every auto-routed batch refines the planner's cost
    /// model.  After [`Coordinator::shutdown`] the queue is gone and
    /// submission fails with the structured [`AttnError::QueueClosed`].
    pub fn submit(&self, mut req: AttnRequest) -> std::result::Result<(), AttnError> {
        // Roll the seeded sampling decision once per request (unless a
        // front end — the net session — already did) and open the
        // request's root span; `respond`/`answer_unserved` close it.
        if req.span == 0 {
            req.span = trace::sample_request(req.id);
        }
        trace::begin(TraceSite::Request, req.span, req.id);
        // Clone the sender out of the slot, then send *outside* the lock:
        // a send blocked on backpressure must not hold up other submitters
        // or the shutdown path.  A clone taken before shutdown closes the
        // slot keeps the batcher's receiver alive until the send lands, so
        // an accepted request is always drained and answered.
        let sender = {
            let slot = lock_unpoisoned(&self.ingress);
            match slot.as_ref() {
                Some(s) => s.clone(),
                None => {
                    trace::end(TraceSite::Request, req.span);
                    return Err(AttnError::QueueClosed);
                }
            }
        };
        sender.send((req, Instant::now())).map_err(|e| {
            trace::end(TraceSite::Request, (e.0).0.span);
            AttnError::QueueClosed
        })
    }

    /// The serving metrics (latency, batching, cache and planner counters).
    pub fn metrics(&self) -> &Metrics {
        &self.metrics
    }

    /// A shared handle to the same metrics, for front ends (the network
    /// serving layer) that outlive any one borrow of the coordinator.
    pub fn metrics_arc(&self) -> Arc<Metrics> {
        self.metrics.clone()
    }

    /// The adaptive planner behind [`Backend::Auto`] routing — exposes the
    /// current cost-model calibration
    /// ([`Planner::snapshot`](crate::planner::Planner::snapshot)) and
    /// accepts out-of-band observations.
    pub fn planner(&self) -> &Planner {
        &self.planner
    }

    /// Apply a [`GraphDelta`] to a served graph and atomically swap every
    /// cached plan over to the patched version (DESIGN.md §14).
    ///
    /// The swap is *publish-then-retire*: the patched BSB is rebuilt (row
    /// windows the delta left untouched are spliced from the previous
    /// version's BSB when the registry still holds it), plans for every
    /// backend cached under the old fingerprint are prepared and inserted
    /// under the new fingerprint **first**, and only then is the old
    /// version evicted.  Concurrent requests therefore always see either
    /// the complete old version or the complete new one — never a
    /// half-patched cache — and in-flight executions keep their
    /// `Arc<Plan>` regardless.
    ///
    /// A panic inside the incremental rebuild (fault injection, latent
    /// bug) is caught and degraded to a from-scratch build of the patched
    /// graph; the update still completes.  Errors *validating* the delta
    /// (stale base fingerprint, out-of-range endpoint, conflicting edit)
    /// reject the update with the base version untouched and still served.
    pub fn update_graph(
        &self,
        base: &CsrGraph,
        delta: &GraphDelta,
    ) -> std::result::Result<UpdateReport, AttnError> {
        let svc = &self.services;
        let (patched, report) = delta
            .applied(base)
            .map_err(|e| AttnError::Unsupported(format!("graph delta rejected: {e:#}")))?;
        let (old_fp, new_fp) = (report.old_fp, report.new_fp);
        // Graph updates carry no request span; sample one keyed on the new
        // fingerprint so splice-vs-rebuild decisions show up in traces.
        let uspan = trace::sample_request(new_fp);

        // Rebuild the BSB, splicing clean row windows from the previous
        // version when the registry still holds a compatible one.
        let mut full_rebuild = false;
        let mut spliced = 0usize;
        let previous = svc
            .bsbs
            .get(old_fp)
            .filter(|old| incremental::compatible(old, &patched));
        let bsb = match previous {
            Some(old) => {
                trace::begin(
                    TraceSite::BsbSplice,
                    uspan,
                    report.dirty_rws.len() as u64,
                );
                let attempt = catch_unwind(AssertUnwindSafe(|| {
                    fault::fire(FaultSite::Prepare)?;
                    Ok::<_, AttnError>(incremental::rebuild(
                        &old,
                        &patched,
                        &report.dirty_rws,
                    ))
                }));
                trace::end(TraceSite::BsbSplice, uspan);
                match attempt {
                    Ok(Ok((bsb, stats))) => {
                        spliced = stats.spliced;
                        bsb
                    }
                    Ok(Err(_)) => {
                        full_rebuild = true;
                        let _b =
                            trace::span(TraceSite::BsbBuild, uspan, patched.n as u64);
                        bsb::build_with(&patched, &svc.engine.pool)
                    }
                    Err(payload) => {
                        svc.metrics.faults.panic_caught();
                        eprintln!(
                            "update_graph: incremental rebuild panicked ({}); \
                             falling back to full rebuild",
                            fault::panic_message(payload.as_ref())
                        );
                        full_rebuild = true;
                        let _b =
                            trace::span(TraceSite::BsbBuild, uspan, patched.n as u64);
                        bsb::build_with(&patched, &svc.engine.pool)
                    }
                }
            }
            None => {
                full_rebuild = true;
                let _b = trace::span(TraceSite::BsbBuild, uspan, patched.n as u64);
                bsb::build_with(&patched, &svc.engine.pool)
            }
        };
        let bsb = Arc::new(bsb);
        svc.bsbs.insert(new_fp, bsb.clone());
        if new_fp != old_fp {
            svc.bsbs.remove(old_fp);
        }

        // Prepare the patched version's plans for every backend currently
        // serving the old fingerprint (or the planner's pick when the old
        // version was never cached), insert them under the new
        // fingerprint, and only then retire the old entries.
        let mut backends = svc.cache.backends_for(old_fp);
        if backends.is_empty() {
            backends.push(svc.planner.resolve(&patched).backend);
        }
        let mut plans_swapped = Vec::new();
        for b in backends {
            let plan = match Plan::from_bsb(&svc.man, (*bsb).clone(), b) {
                Ok(p) => p,
                // Backends that plan from the graph itself (dense, CPU
                // CSR) can't reuse the BSB; plan them from scratch.
                Err(AttnError::Unsupported(_)) => {
                    Plan::new(&svc.man, &patched, b, &svc.engine)?
                }
                Err(e) => return Err(e),
            };
            svc.cache.insert(new_fp, b, patched.n, patched.nnz(), Arc::new(plan));
            plans_swapped.push(b);
        }
        if new_fp != old_fp {
            svc.cache.evict_all(old_fp);
        }

        svc.metrics.streaming.delta_applied(report.dirty_rws.len(), spliced);
        if full_rebuild {
            svc.metrics.streaming.full_rebuild();
        }
        // Backend decisions the batcher memoised against the old topology
        // are stale; bumping the planner epoch invalidates the memo.
        svc.metrics.planner.invalidation();

        Ok(UpdateReport {
            old_fp,
            new_fp,
            patched: Arc::new(patched),
            inserted: report.inserted,
            removed: report.removed,
            dirty_rws: report.dirty_rws.len(),
            spliced_rws: spliced,
            full_rebuild,
            plans_swapped,
        })
    }

    /// Stop all stages, draining every queue — including requests still
    /// parked in the coalescing queue — so each accepted request gets a
    /// response before this returns.  Takes `&self` so a shared
    /// (`Arc`ed) coordinator can be shut down while other threads are
    /// still submitting: their in-flight submissions either drain
    /// normally or fail with [`AttnError::QueueClosed`].  Idempotent —
    /// later calls (and later `submit`s) see a closed queue.  If a
    /// calibration path was configured, the refined cost model is
    /// persisted here.
    pub fn shutdown(&self) {
        drop(lock_unpoisoned(&self.ingress).take());
        let mut stages = lock_unpoisoned(&self.stages);
        if let Some(b) = stages.batcher.take() {
            let _ = b.join();
        }
        for w in stages.workers.drain(..) {
            let _ = w.join();
        }
        if let Some(e) = stages.executor.take() {
            let _ = e.join();
        }
        drop(stages);
        if let Some(path) = &self.calibration_path {
            if let Err(e) = self.planner.save(path) {
                eprintln!("planner: failed to persist calibration: {e:#}");
            }
        }
    }
}

/// Answer a request that never reached execution — validation failure,
/// deadline shed, or an admission-stage fault.  `backend` is `None`: no
/// kernel ran.
fn answer_unserved(
    req: AttnRequest,
    arrived: Instant,
    err: AttnError,
    metrics: &Metrics,
) {
    let latency_s = arrived.elapsed().as_secs_f64();
    metrics.request_done(false);
    metrics.latency.record(latency_s);
    trace::instant(TraceSite::Respond, req.span, 0, 1);
    trace::end(TraceSite::Request, req.span);
    let _ = req.reply.send(AttnResponse {
        id: req.id,
        result: Err(err),
        latency_s,
        preprocess_s: 0.0,
        execute_s: 0.0,
        batch_size: 1,
        backend: None,
        span: req.span,
    });
}

/// Which failures the recovery ladder treats as potentially transient and
/// worth a retry (and, on repeat, a backend switch): prepare and execute
/// faults, including panics converted to structured errors.  `BadShape`
/// is a property of the request and `Unsupported` a deterministic
/// property of the (graph, backend) pair — retrying either is wasted
/// work, so they are answered honestly on the first failure.
fn retryable(e: &AttnError) -> bool {
    matches!(e, AttnError::Prepare(_) | AttnError::Execute(_))
}

fn batcher_loop(
    rx: Receiver<(AttnRequest, Instant)>,
    tx: SyncSender<Job>,
    policy: BatchPolicy,
    planner: Arc<Planner>,
    metrics: Arc<Metrics>,
) {
    let mut co = Coalescer::new(policy);
    let send_all = |tx: &SyncSender<Job>, flushes: Vec<Flush>| -> bool {
        for entries in flushes {
            if !entries.is_empty() && tx.send(Job { entries }).is_err() {
                return false;
            }
        }
        true
    };
    // Rewrite `Backend::Auto` to the planner's choice *before* admission:
    // the coalescer groups on the resolved backend, and downstream the
    // plan cache keys on it too, so auto traffic shares batches and cache
    // entries with explicitly-routed traffic.  The decision's cost cells
    // travel with the request so singleton batches need no second
    // profiling pass.
    //
    // Profiling runs on this single thread, so repeated structures (the
    // serving steady state) memoise their decision by graph fingerprint;
    // an entry is only valid while the calibration epoch (observation
    // count) is unchanged, so online refinement still re-decides.
    let mut decisions: std::collections::HashMap<u64, (u64, Backend, f64)> =
        std::collections::HashMap::new();
    const DECISION_MEMO_CAP: usize = 1024;
    let mut resolve = |req: &mut AttnRequest| -> Option<f64> {
        if req.backend != Backend::Auto {
            return None;
        }
        // Sharding-bound graphs score the *sharded* cost candidate (per-
        // shard fixed overhead + halo-gather cells) over the shardable
        // backends; their measured latency folds per-shard effects the
        // unsharded cell model cannot attribute, so they skip the
        // refinement loop (no tune cells) and the decision memo.
        if req.graph.n > policy.max_plan_nodes {
            let d = planner.resolve_sharded(&req.graph, policy.max_plan_nodes);
            trace::instant(
                TraceSite::PlannerDecision,
                req.span,
                trace::backend_code(d.backend),
                trace::ns(d.predicted_s),
            );
            metrics.planner.auto_resolved(d.backend);
            req.backend = d.backend;
            return None;
        }
        let fp = req.graph.fingerprint();
        let epoch = metrics.planner.epoch();
        let (backend, cells) = match decisions.get(&fp) {
            Some(&(e, b, c)) if e == epoch => (b, c),
            _ => {
                let d = planner.resolve(&req.graph);
                // Per-candidate predicted costs — memo hits skip the
                // scoring pass, so these only appear on fresh resolutions.
                for sc in &d.scores {
                    trace::instant(
                        TraceSite::PlannerScore,
                        req.span,
                        trace::backend_code(sc.backend),
                        trace::ns(sc.predicted_s.unwrap_or(0.0)),
                    );
                }
                if decisions.len() >= DECISION_MEMO_CAP {
                    decisions.clear();
                }
                decisions.insert(fp, (epoch, d.backend, d.cells));
                (d.backend, d.cells)
            }
        };
        trace::instant(
            TraceSite::PlannerDecision,
            req.span,
            trace::backend_code(backend),
            cells as u64,
        );
        metrics.planner.auto_resolved(backend);
        req.backend = backend;
        Some(cells)
    };
    // Admit one request: shed it if it aged out in the ingress queue,
    // resolve its backend behind a panic boundary (planner resolution runs
    // cost-model code; a panic here must not kill the batcher and strand
    // every queue), then hand it to the coalescer.  Returns false only
    // when downstream has shut down.
    let mut process = |co: &mut Coalescer, mut req: AttnRequest, arrived: Instant| -> bool {
        if req.deadline.map_or(false, |d| arrived.elapsed() >= d) {
            metrics.faults.deadline_shed();
            trace::instant(TraceSite::DeadlineShed, req.span, 0, 0);
            answer_unserved(req, arrived, AttnError::DeadlineExceeded, &metrics);
            return true;
        }
        trace::begin(TraceSite::Admission, req.span, req.graph.n as u64);
        let rolled = catch_unwind(AssertUnwindSafe(
            || -> std::result::Result<Option<f64>, AttnError> {
                fault::fire(FaultSite::Batch)?;
                Ok(resolve(&mut req))
            },
        ));
        trace::end(TraceSite::Admission, req.span);
        let auto = match rolled {
            Ok(Ok(cells)) => cells,
            Ok(Err(e)) => {
                answer_unserved(req, arrived, e, &metrics);
                return true;
            }
            Err(payload) => {
                metrics.faults.panic_caught();
                let e = AttnError::Execute(format!(
                    "panic during admission: {}",
                    fault::panic_message(payload.as_ref())
                ));
                answer_unserved(req, arrived, e, &metrics);
                return true;
            }
        };
        send_all(&tx, co.admit(req, arrived, auto))
    };
    loop {
        // Block outright while nothing is parked (a deadline can only be
        // created by a new request); wake for the earliest deadline —
        // group flush or member expiry — otherwise.  Deadlines count from
        // *submit* time, so a request that aged in the ingress queue
        // flushes (or sheds) promptly.
        let msg = match co.next_deadline() {
            None => match rx.recv() {
                Ok(m) => Some(m),
                Err(_) => None, // shutdown with an empty queue
            },
            Some(deadline) => {
                let timeout = deadline.saturating_duration_since(Instant::now());
                match rx.recv_timeout(timeout) {
                    Ok(m) => Some(m),
                    Err(std::sync::mpsc::RecvTimeoutError::Timeout) => {
                        let now = Instant::now();
                        for a in co.shed_expired(now) {
                            metrics.faults.deadline_shed();
                            trace::instant(TraceSite::DeadlineShed, a.req.span, 0, 0);
                            answer_unserved(
                                a.req,
                                a.arrived,
                                AttnError::DeadlineExceeded,
                                &metrics,
                            );
                        }
                        if !send_all(&tx, co.flush_due(now)) {
                            return;
                        }
                        continue;
                    }
                    Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => {
                        // Shutdown: drain the coalescing queue — every
                        // admitted request must still be served.
                        let _ = send_all(&tx, co.flush_all());
                        return;
                    }
                }
            }
        };
        let Some((req, arrived)) = msg else {
            return;
        };
        if !process(&mut co, req, arrived) {
            return;
        }
        // Greedily admit everything already queued before honouring
        // deadlines: a backlogged burst (requests that aged in the ingress
        // while the stages downstream were busy) still coalesces by
        // capacity instead of trickling out as overdue singletons.
        loop {
            match rx.try_recv() {
                Ok((req, arrived)) => {
                    if !process(&mut co, req, arrived) {
                        return;
                    }
                }
                Err(std::sync::mpsc::TryRecvError::Empty) => break,
                Err(std::sync::mpsc::TryRecvError::Disconnected) => {
                    let _ = send_all(&tx, co.flush_all());
                    return;
                }
            }
        }
        let now = Instant::now();
        for a in co.shed_expired(now) {
            metrics.faults.deadline_shed();
            trace::instant(TraceSite::DeadlineShed, a.req.span, 0, 0);
            answer_unserved(a.req, a.arrived, AttnError::DeadlineExceeded, &metrics);
        }
        if !send_all(&tx, co.flush_due(now)) {
            return;
        }
    }
}

fn preprocess_worker(
    rx: Arc<Mutex<Receiver<Job>>>,
    tx: SyncSender<PreparedBatch>,
    svc: Arc<Services>,
) {
    loop {
        let job = {
            let guard = lock_unpoisoned(&rx);
            match guard.recv() {
                Ok(j) => j,
                Err(_) => return, // batcher exited after draining
            }
        };
        for prepared in prepare_job(job, &svc) {
            if tx.send(prepared).is_err() {
                return;
            }
        }
    }
}

/// Validate, merge, and prepare one coalesced job.  Expired members are
/// shed and invalid members answered immediately; the valid remainder
/// becomes one block-diagonal head-major problem with a shared (possibly
/// cached) plan.  If *merged* preparation fails — e.g. the unfused
/// baseline's oversize refusal on a boundary window that only exists in
/// the merged graph, or an injected/real prepare fault the ladder could
/// not recover — the members fall back to singleton preparation rather
/// than failing as a unit.
fn prepare_job(job: Job, svc: &Services) -> Vec<PreparedBatch> {
    let metrics = &svc.metrics;
    let now = Instant::now();
    let mut valid: Vec<Admitted> = Vec::with_capacity(job.entries.len());
    for a in job.entries {
        if a.expired(now) {
            metrics.faults.deadline_shed();
            trace::instant(TraceSite::DeadlineShed, a.req.span, 0, 0);
            answer_unserved(a.req, a.arrived, AttnError::DeadlineExceeded, metrics);
            continue;
        }
        match a.req.validate() {
            Ok(()) => valid.push(a),
            Err(e) => answer_unserved(a.req, a.arrived, e, metrics),
        }
    }
    if valid.is_empty() {
        return Vec::new();
    }
    if valid.len() == 1 {
        // invariant: len() == 1 was just checked.
        let a = valid.pop().expect("one entry");
        return vec![prepare_single(a, svc)];
    }

    let t0 = Instant::now();
    let d = valid[0].req.d;
    let dv = valid[0].req.dv;
    let heads = valid[0].req.heads;
    let scale = valid[0].req.scale;
    let backend = valid[0].req.backend;
    let wants_tune = valid.iter().any(|a| a.auto_cells.is_some());
    // Every traced member gets its own Prepare span (so per-request
    // nesting holds across coalescing); inner seams (cache hit/miss, BSB
    // build, shard prepare, ladder steps) attribute to the first traced
    // member's span via the ambient thread-local.
    let spans: Vec<u64> =
        valid.iter().map(|a| a.req.span).filter(|&s| s != 0).collect();
    for a in &valid {
        trace::instant(
            TraceSite::CoalesceWait,
            a.req.span,
            a.arrived.elapsed().as_micros() as u64,
            valid.len() as u64,
        );
    }
    let refs: Vec<&CsrGraph> = valid.iter().map(|a| &a.req.graph).collect();
    let (merged, offsets) = batch_graph_refs(&refs);
    for &s in &spans {
        trace::begin(TraceSite::Prepare, s, merged.n as u64);
    }
    let primary = spans.first().copied().unwrap_or(0);
    let (plan, used) =
        trace::with_span(primary, || plan_with_recovery(&merged, backend, svc));
    for &s in &spans {
        trace::end(TraceSite::Prepare, s);
    }
    match plan {
        Ok(plan) => {
            // The merged block-diagonal structure differs from any member's,
            // so a coalesced auto batch is profiled once here; singletons
            // reuse the cells the batcher's resolution already computed.
            // A ladder-degraded batch skips tuning: its cells were priced
            // for a backend that is not the one about to be measured.
            let tune = if wants_tune && used == backend {
                tune_info(&merged, used, heads, d)
            } else {
                None
            };
            // Merge per-request head-major features into one head-major
            // problem over the block-diagonal graph: head h's block is the
            // in-order concatenation of every component's head-h rows
            // (components appear in `offsets` order), so the merge is
            // append-only — heads outer, components inner, no zero fill.
            // (For heads == 1 this degenerates to plain concatenation.)
            let n_total = merged.n;
            let fp = merged.fingerprint();
            let mut q = Vec::with_capacity(heads * n_total * d);
            let mut k = Vec::with_capacity(heads * n_total * d);
            let mut v = Vec::with_capacity(heads * n_total * dv);
            for h in 0..heads {
                for a in &valid {
                    let ni = a.req.graph.n;
                    q.extend_from_slice(&a.req.q[h * ni * d..(h + 1) * ni * d]);
                    k.extend_from_slice(&a.req.k[h * ni * d..(h + 1) * ni * d]);
                    v.extend_from_slice(&a.req.v[h * ni * dv..(h + 1) * ni * dv]);
                }
            }
            let entries: Vec<Entry> = valid
                .into_iter()
                .map(|a| Entry {
                    id: a.req.id,
                    span: a.req.span,
                    reply: a.req.reply,
                    arrived: a.arrived,
                    expires: a.expires,
                    graph: a.req.graph,
                })
                .collect();
            metrics.batching.record_batch(entries.len());
            vec![PreparedBatch {
                entries,
                offsets,
                n_total,
                d,
                dv,
                heads,
                scale,
                q,
                k,
                v,
                plan: Ok(plan),
                backend: used,
                fp,
                preprocess_s: t0.elapsed().as_secs_f64(),
                tune,
            }]
        }
        // Merged preparation failed even after the ladder: requests that
        // would succeed alone must not fail because of who they were
        // batched with.
        Err(_) => valid
            .into_iter()
            .map(|a| prepare_single(a, svc))
            .collect(),
    }
}

/// Prepare one request as its own (singleton) batch, feature buffers moved
/// rather than copied.
fn prepare_single(a: Admitted, svc: &Services) -> PreparedBatch {
    let t0 = Instant::now();
    let span = a.req.span;
    trace::instant(
        TraceSite::CoalesceWait,
        span,
        a.arrived.elapsed().as_micros() as u64,
        1,
    );
    trace::begin(TraceSite::Prepare, span, a.req.graph.n as u64);
    let (plan, used) = trace::with_span(span, || {
        plan_with_recovery(&a.req.graph, a.req.backend, svc)
    });
    trace::end(TraceSite::Prepare, span);
    svc.metrics.batching.record_batch(1);
    let tune = match (a.auto_cells, plan.is_ok() && used == a.req.backend) {
        (Some(cells), true) => Some(TuneInfo {
            backend: a.req.backend,
            cells: planner::effective_cells(cells, a.req.heads, a.req.d),
        }),
        _ => None,
    };
    let n = a.req.graph.n;
    let fp = a.req.graph.fingerprint();
    let entry = Entry {
        id: a.req.id,
        span,
        reply: a.req.reply,
        arrived: a.arrived,
        expires: a.expires,
        graph: a.req.graph,
    };
    PreparedBatch {
        entries: vec![entry],
        offsets: vec![0, n as u32],
        n_total: n,
        d: a.req.d,
        dv: a.req.dv,
        heads: a.req.heads,
        scale: a.req.scale,
        q: a.req.q,
        k: a.req.k,
        v: a.req.v,
        plan,
        backend: used,
        fp,
        preprocess_s: t0.elapsed().as_secs_f64(),
        tune,
    }
}

/// Refinement payload for a batch executed on `backend` over `graph`: the
/// cost cells the model would have priced, scaled to the executed
/// `heads`/`d` shape ([`planner::effective_cells`]) and paired later with
/// the measured execute time.  `None` when the backend has no cost-cell
/// mapping for the graph (never true for a backend the planner itself
/// chose).
fn tune_info(
    graph: &CsrGraph,
    backend: Backend,
    heads: usize,
    d: usize,
) -> Option<TuneInfo> {
    let profile = GraphProfile::from_csr(graph);
    planner::cells(backend, &profile).map(|cells| TuneInfo {
        backend,
        cells: planner::effective_cells(cells, heads, d),
    })
}

/// The prepare-time arm of the degradation ladder.  Attempts to plan
/// `graph` on the requested backend — steered away up front if that pair
/// is already quarantined — retrying a retryable failure once; a second
/// failure quarantines the `(fingerprint, backend)` pair, evicts the
/// possibly-poisoned cache entry, and re-resolves through the planner
/// over the backends not yet tried or quarantined.  Returns the plan
/// result and the backend it was (last) attempted on.
///
/// Availability first: if the requested backend is quarantined but no
/// alternative is feasible, the quarantined backend is re-probed anyway —
/// refusing the request outright would turn one transient fault into an
/// outage for that structure.
fn plan_with_recovery(
    graph: &CsrGraph,
    requested: Backend,
    svc: &Services,
) -> (std::result::Result<Arc<Plan>, AttnError>, Backend) {
    let fp = graph.fingerprint();
    let span = trace::current_span();
    let mut backend = requested;
    if svc.quarantine.contains(fp, requested) {
        let exclude = svc.quarantine.quarantined_for(fp);
        if let Some(d) = svc.planner.resolve_excluding(graph, &exclude) {
            svc.metrics.faults.fallback();
            trace::instant(
                TraceSite::Fallback,
                span,
                trace::backend_code(d.backend),
                0,
            );
            backend = d.backend;
        }
    }
    let mut tried: Vec<Backend> = Vec::new();
    loop {
        let result = match try_prepare(graph, backend, svc) {
            Err(e) if retryable(&e) => {
                svc.metrics.faults.retry();
                trace::instant(
                    TraceSite::Retry,
                    span,
                    trace::backend_code(backend),
                    0,
                );
                try_prepare(graph, backend, svc)
            }
            other => other,
        };
        match result {
            Ok(plan) => return (Ok(plan), backend),
            Err(e) if retryable(&e) => {
                svc.quarantine.insert(fp, backend);
                svc.metrics.faults.quarantine();
                trace::instant(
                    TraceSite::Quarantine,
                    span,
                    trace::backend_code(backend),
                    fp,
                );
                svc.cache.evict(fp, backend);
                tried.push(backend);
                let mut exclude = svc.quarantine.quarantined_for(fp);
                exclude.extend(tried.iter().copied());
                match svc.planner.resolve_excluding(graph, &exclude) {
                    Some(d) => {
                        svc.metrics.faults.fallback();
                        trace::instant(
                            TraceSite::Fallback,
                            span,
                            trace::backend_code(d.backend),
                            0,
                        );
                        backend = d.backend;
                    }
                    None => return (Err(e), backend),
                }
            }
            Err(e) => return (Err(e), backend),
        }
    }
}

/// One guarded plan-preparation attempt: a panic anywhere under the BSB
/// build or bucket planning is caught and converted to a structured
/// [`AttnError::Prepare`] so the worker thread survives and the ladder
/// can react.
fn try_prepare(
    graph: &CsrGraph,
    backend: Backend,
    svc: &Services,
) -> std::result::Result<Arc<Plan>, AttnError> {
    match catch_unwind(AssertUnwindSafe(|| shared_plan(graph, backend, svc))) {
        Ok(r) => r,
        Err(payload) => {
            svc.metrics.faults.panic_caught();
            Err(AttnError::Prepare(format!(
                "panic during prepare on {backend:?}: {}",
                fault::panic_message(payload.as_ref())
            )))
        }
    }
}

/// Resolve the prepared plan for a graph: graphs above the node cap take
/// the partition-parallel sharded path; everything else goes through the
/// fingerprint-keyed cache (build and insert on miss).
fn shared_plan(
    graph: &CsrGraph,
    backend: Backend,
    svc: &Services,
) -> std::result::Result<Arc<Plan>, AttnError> {
    if graph.n > svc.route.max_plan_nodes {
        return sharded_plan(graph, backend, svc);
    }
    cached_plan(graph, backend, svc)
}

/// Build a [`ShardedPlan`] for a graph above the node cap, sourcing each
/// shard's plan through the fingerprint cache — the shard-local graph's
/// own fingerprint is the key, so a replayed mega-graph rebuilds only its
/// halo maps while every shard's BSB + bucket plan comes from cache.
/// A failure (or caught panic) inside one shard's preparation surfaces as
/// a structured `AttnError::Prepare` naming the shard, failing only this
/// request ([`ShardedPlan::build`] isolates per-shard panics).
fn sharded_plan(
    graph: &CsrGraph,
    backend: Backend,
    svc: &Services,
) -> std::result::Result<Arc<Plan>, AttnError> {
    if svc.route.max_shards <= 1 {
        return Err(AttnError::Unsupported(format!(
            "graph n={} exceeds max_plan_nodes={} and sharding is disabled \
             (max_shards={})",
            graph.n, svc.route.max_plan_nodes, svc.route.max_shards
        )));
    }
    let shards = graph
        .n
        .div_ceil(svc.route.max_plan_nodes)
        .clamp(2, svc.route.max_shards);
    let span = trace::current_span();
    let mut shard_idx = 0u64;
    let sharded = ShardedPlan::build(
        graph,
        backend,
        ShardPolicy::balanced(shards),
        &mut |local, b| {
            let sp =
                trace::span(TraceSite::ShardPrepare, span, shard_idx);
            shard_idx += 1;
            let plan = cached_plan(local, b, svc);
            drop(sp);
            plan
        },
    )?;
    let stats = sharded.stats();
    svc.metrics.sharding.record_batch(stats.shards, stats.halo_rows);
    Ok(Arc::new(Plan::from_sharded(sharded)))
}

/// The single-plan cache path: fingerprint-keyed lookup, build (and
/// insert) on miss.  This is the leaf every prepare route funnels through
/// (whole graphs and individual shards alike), so the prepare-seam fault
/// hook lives here.
fn cached_plan(
    graph: &CsrGraph,
    backend: Backend,
    svc: &Services,
) -> std::result::Result<Arc<Plan>, AttnError> {
    fault::fire(FaultSite::Prepare)?;
    let fp = graph.fingerprint();
    let span = trace::current_span();
    if let Some(plan) = svc.cache.get(fp, backend, graph.n, graph.nnz()) {
        svc.metrics.batching.cache_hit();
        trace::instant(TraceSite::CacheHit, span, fp, 0);
        return Ok(plan);
    }
    svc.metrics.batching.cache_miss();
    trace::instant(TraceSite::CacheMiss, span, fp, 0);
    let _build = trace::span(TraceSite::BsbBuild, span, graph.n as u64);
    match Plan::new(&svc.man, graph, backend, &svc.engine) {
        Ok(plan) => {
            let plan = Arc::new(plan);
            let evicted =
                svc.cache.insert(fp, backend, graph.n, graph.nnz(), plan.clone());
            svc.metrics.batching.cache_evicted(evicted);
            Ok(plan)
        }
        Err(e) => Err(e),
    }
}

/// What the executor thread dispatches through.
enum ExecBackend {
    Pjrt(Runtime),
    Host,
}

/// One guarded execution of a prepared plan: a panic anywhere under the
/// kernels (including panics propagated out of the engine's scoped
/// gather/scatter threads) is caught and converted to a structured
/// [`AttnError::Execute`].
fn exec_guarded(
    plan: &Plan,
    x: &AttentionBatch,
    svc: &Services,
    exec: &ExecBackend,
) -> std::result::Result<Vec<f32>, AttnError> {
    let run = || {
        let mut ctx = match exec {
            ExecBackend::Pjrt(rt) => ExecCtx::pjrt(rt, &svc.engine),
            ExecBackend::Host => ExecCtx::host(&svc.engine),
        };
        plan.execute(&mut ctx, x)
    };
    match catch_unwind(AssertUnwindSafe(run)) {
        Ok(r) => r,
        Err(payload) => {
            svc.metrics.faults.panic_caught();
            Err(AttnError::Execute(format!(
                "panic during execute: {}",
                fault::panic_message(payload.as_ref())
            )))
        }
    }
}

/// One full ladder rung for a backend: guarded prepare + guarded execute,
/// with a single retry of the whole attempt on a retryable failure.
fn attempt_backend(
    graph: &CsrGraph,
    x: &AttentionBatch,
    backend: Backend,
    svc: &Services,
    exec: &ExecBackend,
) -> std::result::Result<Vec<f32>, AttnError> {
    let once = || -> std::result::Result<Vec<f32>, AttnError> {
        let plan = try_prepare(graph, backend, svc)?;
        exec_guarded(&plan, x, svc, exec)
    };
    match once() {
        Err(e) if retryable(&e) => {
            svc.metrics.faults.retry();
            trace::instant(
                TraceSite::Retry,
                trace::current_span(),
                trace::backend_code(backend),
                1,
            );
            once()
        }
        other => other,
    }
}

/// A failed batch member being re-served alone: its slice of the merged
/// head-major problem, re-gathered from the batch buffers.
struct SingletonWork {
    entry: Entry,
    q: Vec<f32>,
    k: Vec<f32>,
    v: Vec<f32>,
    d: usize,
    dv: usize,
    heads: usize,
    scale: f32,
    /// First rung of the ladder: the backend the failed batch ran on.
    start: Backend,
    preprocess_s: f64,
    batch_size: usize,
}

/// Serve one request alone through the degradation ladder, starting on
/// the batch's original backend: an innocent member of a failed merged
/// batch most likely succeeds immediately — on the backend, and therefore
/// with the bits, it was originally routed to.  Members that keep failing
/// walk backend fallbacks until the candidate set is exhausted.
fn serve_singleton(w: SingletonWork, svc: &Services, exec: &ExecBackend) {
    // The entry's span becomes ambient so the inner prepare seams
    // (cache, BSB build, ladder) attribute to this request.
    let span = w.entry.span;
    trace::with_span(span, move || serve_singleton_inner(w, svc, exec))
}

fn serve_singleton_inner(w: SingletonWork, svc: &Services, exec: &ExecBackend) {
    let SingletonWork {
        entry,
        q,
        k,
        v,
        d,
        dv,
        heads,
        scale,
        start,
        preprocess_s,
        batch_size,
    } = w;
    let fp = entry.graph.fingerprint();
    let span = entry.span;
    let x = AttentionBatch::new(entry.graph.n, d, dv, heads, &q, &k, &v, scale);
    let t0 = Instant::now();
    trace::begin(TraceSite::Execute, span, entry.graph.n as u64);
    let mut backend = start;
    // The merged batch quarantined its *own* fingerprint; this entry's
    // (fp, start) pair may be untainted, so only steer away if it too is
    // quarantined.
    if svc.quarantine.contains(fp, backend) {
        let exclude = svc.quarantine.quarantined_for(fp);
        if let Some(dec) = svc.planner.resolve_excluding(&entry.graph, &exclude) {
            svc.metrics.faults.fallback();
            trace::instant(
                TraceSite::Fallback,
                span,
                trace::backend_code(dec.backend),
                0,
            );
            backend = dec.backend;
        }
    }
    let mut tried: Vec<Backend> = Vec::new();
    loop {
        match attempt_backend(&entry.graph, &x, backend, svc, exec) {
            Ok(out) => {
                let execute_s = t0.elapsed().as_secs_f64();
                svc.metrics.execute.record(execute_s);
                trace::end(TraceSite::Execute, span);
                respond(
                    entry,
                    Ok(out),
                    &svc.metrics,
                    preprocess_s,
                    execute_s,
                    batch_size,
                    Some(backend),
                );
                return;
            }
            Err(e) if retryable(&e) => {
                svc.quarantine.insert(fp, backend);
                svc.metrics.faults.quarantine();
                trace::instant(
                    TraceSite::Quarantine,
                    span,
                    trace::backend_code(backend),
                    fp,
                );
                svc.cache.evict(fp, backend);
                tried.push(backend);
                let mut exclude = svc.quarantine.quarantined_for(fp);
                exclude.extend(tried.iter().copied());
                match svc.planner.resolve_excluding(&entry.graph, &exclude) {
                    Some(dec) => {
                        svc.metrics.faults.fallback();
                        trace::instant(
                            TraceSite::Fallback,
                            span,
                            trace::backend_code(dec.backend),
                            0,
                        );
                        backend = dec.backend;
                    }
                    None => {
                        let execute_s = t0.elapsed().as_secs_f64();
                        trace::end(TraceSite::Execute, span);
                        respond(
                            entry,
                            Err(e),
                            &svc.metrics,
                            preprocess_s,
                            execute_s,
                            batch_size,
                            None,
                        );
                        return;
                    }
                }
            }
            Err(e) => {
                let execute_s = t0.elapsed().as_secs_f64();
                trace::end(TraceSite::Execute, span);
                respond(
                    entry,
                    Err(e),
                    &svc.metrics,
                    preprocess_s,
                    execute_s,
                    batch_size,
                    None,
                );
                return;
            }
        }
    }
}

fn executor_loop(exec: ExecBackend, rx: Receiver<PreparedBatch>, svc: Arc<Services>) {
    while let Ok(p) = rx.recv() {
        let batch_size = p.entries.len();
        svc.metrics.preprocess.record(p.preprocess_s);
        // Shed members whose deadline passed while the batch sat in the
        // worker → executor queue; execution is the last point where
        // shedding still saves the kernel time.  Original indices are
        // kept so survivors still scatter by `offsets`.
        let now = Instant::now();
        let mut live: Vec<(usize, Entry)> = Vec::with_capacity(batch_size);
        for (i, entry) in p.entries.into_iter().enumerate() {
            if entry.expired(now) {
                svc.metrics.faults.deadline_shed();
                trace::instant(TraceSite::DeadlineShed, entry.span, 1, 0);
                respond(
                    entry,
                    Err(AttnError::DeadlineExceeded),
                    &svc.metrics,
                    p.preprocess_s,
                    0.0,
                    batch_size,
                    None,
                );
            } else {
                live.push((i, entry));
            }
        }
        if live.is_empty() {
            continue;
        }
        let plan = match p.plan {
            Ok(plan) => plan,
            Err(e) => {
                // Preparation already walked the ladder and still failed;
                // the error is structural (or the candidate set ran dry).
                svc.metrics.execute.record(0.0);
                for (_, entry) in live {
                    respond(
                        entry,
                        Err(e.clone()),
                        &svc.metrics,
                        p.preprocess_s,
                        0.0,
                        batch_size,
                        None,
                    );
                }
                continue;
            }
        };
        let t0 = Instant::now();
        let x = AttentionBatch::new(
            p.n_total, p.d, p.dv, p.heads, &p.q, &p.k, &p.v, p.scale,
        );
        // One Execute span per traced member (per-request nesting); the
        // engine-stage spans inside attribute to the first traced member.
        let spans: Vec<u64> =
            live.iter().map(|(_, e)| e.span).filter(|&s| s != 0).collect();
        let primary = spans.first().copied().unwrap_or(0);
        for &s in &spans {
            trace::begin(TraceSite::Execute, s, p.n_total as u64);
        }
        let result = trace::with_span(primary, || {
            let mut result = exec_guarded(&plan, &x, &svc, &exec);
            if let Err(e) = &result {
                if retryable(e) {
                    svc.metrics.faults.retry();
                    trace::instant(
                        TraceSite::Retry,
                        primary,
                        trace::backend_code(p.backend),
                        1,
                    );
                    result = exec_guarded(&plan, &x, &svc, &exec);
                }
            }
            result
        });
        for &s in &spans {
            trace::end(TraceSite::Execute, s);
        }
        let execute_s = t0.elapsed().as_secs_f64();
        svc.metrics.execute.record(execute_s);
        // The online refinement loop: planner-routed batches feed their
        // measured kernel latency back into the cost-model calibration.
        if let (Some(t), Ok(_)) = (&p.tune, &result) {
            svc.planner.observe(t.backend, t.cells, execute_s);
            svc.metrics.planner.observation();
        }
        match result {
            Ok(out) => {
                for (i, entry) in live {
                    // Gather this component's rows out of every head block
                    // of the merged head-major output.
                    let lo = p.offsets[i] as usize;
                    let hi = p.offsets[i + 1] as usize;
                    let ni = hi - lo;
                    let mut comp = Vec::with_capacity(p.heads * ni * p.dv);
                    for h in 0..p.heads {
                        let base = (h * p.n_total + lo) * p.dv;
                        comp.extend_from_slice(&out[base..base + ni * p.dv]);
                    }
                    respond(
                        entry,
                        Ok(comp),
                        &svc.metrics,
                        p.preprocess_s,
                        execute_s,
                        batch_size,
                        Some(p.backend),
                    );
                }
            }
            Err(e) if retryable(&e) => {
                // Second execute failure on this prepared plan: quarantine
                // the pair, evict the possibly-poisoned cache entry, and
                // re-serve each surviving member alone so one bad request
                // cannot fail its batch-mates.
                svc.quarantine.insert(p.fp, p.backend);
                svc.metrics.faults.quarantine();
                trace::instant(
                    TraceSite::Quarantine,
                    primary,
                    trace::backend_code(p.backend),
                    p.fp,
                );
                svc.cache.evict(p.fp, p.backend);
                for (i, entry) in live {
                    let lo = p.offsets[i] as usize;
                    let hi = p.offsets[i + 1] as usize;
                    let ni = hi - lo;
                    let mut q = Vec::with_capacity(p.heads * ni * p.d);
                    let mut k = Vec::with_capacity(p.heads * ni * p.d);
                    let mut v = Vec::with_capacity(p.heads * ni * p.dv);
                    for h in 0..p.heads {
                        let qk = (h * p.n_total + lo) * p.d;
                        q.extend_from_slice(&p.q[qk..qk + ni * p.d]);
                        k.extend_from_slice(&p.k[qk..qk + ni * p.d]);
                        let vb = (h * p.n_total + lo) * p.dv;
                        v.extend_from_slice(&p.v[vb..vb + ni * p.dv]);
                    }
                    serve_singleton(
                        SingletonWork {
                            entry,
                            q,
                            k,
                            v,
                            d: p.d,
                            dv: p.dv,
                            heads: p.heads,
                            scale: p.scale,
                            start: p.backend,
                            preprocess_s: p.preprocess_s,
                            batch_size,
                        },
                        &svc,
                        &exec,
                    );
                }
            }
            Err(e) => {
                for (_, entry) in live {
                    respond(
                        entry,
                        Err(e.clone()),
                        &svc.metrics,
                        p.preprocess_s,
                        execute_s,
                        batch_size,
                        None,
                    );
                }
            }
        }
    }
}

fn respond(
    entry: Entry,
    result: std::result::Result<Vec<f32>, AttnError>,
    metrics: &Metrics,
    preprocess_s: f64,
    execute_s: f64,
    batch_size: usize,
    backend: Option<Backend>,
) {
    let latency_s = entry.arrived.elapsed().as_secs_f64();
    metrics.request_done(result.is_ok());
    metrics.latency.record(latency_s);
    trace::instant(
        TraceSite::Respond,
        entry.span,
        u64::from(result.is_ok()),
        batch_size as u64,
    );
    trace::end(TraceSite::Request, entry.span);
    let _ = entry.reply.send(AttnResponse {
        id: entry.id,
        result,
        latency_s,
        preprocess_s,
        execute_s,
        batch_size,
        backend,
        span: entry.span,
    });
}
