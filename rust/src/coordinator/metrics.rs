//! Serving metrics: latency distribution + throughput + queue accounting.

use std::sync::Mutex;
use std::time::Instant;

use crate::util::stats;

/// Thread-safe latency recorder.
#[derive(Default)]
pub struct LatencyRecorder {
    samples: Mutex<Vec<f64>>,
}

impl LatencyRecorder {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record(&self, seconds: f64) {
        self.samples.lock().unwrap().push(seconds);
    }

    pub fn snapshot(&self) -> LatencySummary {
        let mut v = self.samples.lock().unwrap().clone();
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        LatencySummary {
            count: v.len(),
            p50_s: stats::percentile_sorted(&v, 50.0),
            p95_s: stats::percentile_sorted(&v, 95.0),
            p99_s: stats::percentile_sorted(&v, 99.0),
            mean_s: stats::mean(&v),
            max_s: v.last().copied().unwrap_or(0.0),
        }
    }
}

#[derive(Clone, Debug, Default)]
pub struct LatencySummary {
    pub count: usize,
    pub p50_s: f64,
    pub p95_s: f64,
    pub p99_s: f64,
    pub mean_s: f64,
    pub max_s: f64,
}

/// Aggregate serving metrics over a run.
pub struct Metrics {
    pub latency: LatencyRecorder,
    pub preprocess: LatencyRecorder,
    pub execute: LatencyRecorder,
    started: Instant,
    completed: Mutex<u64>,
    failed: Mutex<u64>,
}

impl Default for Metrics {
    fn default() -> Self {
        Metrics {
            latency: LatencyRecorder::new(),
            preprocess: LatencyRecorder::new(),
            execute: LatencyRecorder::new(),
            started: Instant::now(),
            completed: Mutex::new(0),
            failed: Mutex::new(0),
        }
    }
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn request_done(&self, ok: bool) {
        if ok {
            *self.completed.lock().unwrap() += 1;
        } else {
            *self.failed.lock().unwrap() += 1;
        }
    }

    pub fn completed(&self) -> u64 {
        *self.completed.lock().unwrap()
    }

    pub fn failed(&self) -> u64 {
        *self.failed.lock().unwrap()
    }

    /// Completed requests per second since construction.
    pub fn throughput_rps(&self) -> f64 {
        let elapsed = self.started.elapsed().as_secs_f64();
        if elapsed == 0.0 {
            0.0
        } else {
            self.completed() as f64 / elapsed
        }
    }

    pub fn report(&self) -> String {
        let l = self.latency.snapshot();
        format!(
            "requests={} failed={} throughput={:.2} req/s  \
             latency p50={:.2}ms p95={:.2}ms p99={:.2}ms max={:.2}ms",
            self.completed(),
            self.failed(),
            self.throughput_rps(),
            l.p50_s * 1e3,
            l.p95_s * 1e3,
            l.p99_s * 1e3,
            l.max_s * 1e3,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn latency_percentiles() {
        let r = LatencyRecorder::new();
        for i in 1..=100 {
            r.record(i as f64 / 1000.0);
        }
        let s = r.snapshot();
        assert_eq!(s.count, 100);
        assert!((s.p50_s - 0.0505).abs() < 1e-3);
        assert!(s.p99_s > 0.098 && s.p99_s <= 0.1);
        assert_eq!(s.max_s, 0.1);
    }

    #[test]
    fn counters() {
        let m = Metrics::new();
        m.request_done(true);
        m.request_done(true);
        m.request_done(false);
        assert_eq!(m.completed(), 2);
        assert_eq!(m.failed(), 1);
        assert!(m.report().contains("requests=2"));
    }
}
