//! Serving metrics: latency distribution + throughput + queue accounting +
//! batching/cache counters for the coalescing path + adaptive-planner
//! counters for the [`Backend::Auto`](crate::kernels::Backend::Auto) path.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Instant;

use crate::kernels::Backend;
use crate::util::json::{arr, num, obj, Json};
use crate::util::stats;
use crate::util::sync::lock_unpoisoned;

/// Log-spaced latency histogram buckets.  Bucket 0 catches everything at
/// or below 1 µs (including NaN/negative junk from upstream bugs); bucket
/// `i ≥ 1` covers `[bucket_floor_s(i), bucket_floor_s(i+1))`, doubling
/// each step, so the last bucket opens at `1 µs · 2^26 ≈ 67 s` — wide
/// enough for any latency this stack can produce.
pub const HIST_BUCKETS: usize = 28;

/// Closed-form lower bound of histogram bucket `i`, in seconds:
/// `0` for bucket 0, `1e-6 · 2^(i-1)` otherwise.
pub fn bucket_floor_s(i: usize) -> f64 {
    if i == 0 {
        0.0
    } else {
        1e-6 * f64::powi(2.0, (i - 1) as i32)
    }
}

/// Which histogram bucket a sample lands in.  Monotone in `seconds`, and
/// total: NaN and negatives land in bucket 0 rather than panicking.
pub fn bucket_index(seconds: f64) -> usize {
    if !(seconds > 1e-6) {
        return 0;
    }
    let mut i = 1;
    while i + 1 < HIST_BUCKETS && seconds >= bucket_floor_s(i + 1) {
        i += 1;
    }
    i
}

/// Thread-safe latency recorder: accumulates raw per-event samples and
/// summarises them on demand.
#[derive(Default)]
pub struct LatencyRecorder {
    samples: Mutex<Vec<f64>>,
}

impl LatencyRecorder {
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one latency sample, in seconds.
    pub fn record(&self, seconds: f64) {
        lock_unpoisoned(&self.samples).push(seconds);
    }

    /// Percentile summary over every sample recorded so far.
    pub fn snapshot(&self) -> LatencySummary {
        let mut v = lock_unpoisoned(&self.samples).clone();
        // total_cmp: a NaN sample (a bug upstream) must not panic the
        // metrics reader.
        v.sort_by(f64::total_cmp);
        LatencySummary {
            count: v.len(),
            p50_s: stats::percentile_sorted(&v, 50.0),
            p95_s: stats::percentile_sorted(&v, 95.0),
            p99_s: stats::percentile_sorted(&v, 99.0),
            mean_s: stats::mean(&v),
            max_s: v.last().copied().unwrap_or(0.0),
        }
    }

    /// Log-spaced distribution over every sample recorded so far:
    /// `counts[i]` samples fell in
    /// `[bucket_floor_s(i), bucket_floor_s(i+1))`.
    pub fn histogram(&self) -> [u64; HIST_BUCKETS] {
        let v = lock_unpoisoned(&self.samples);
        let mut counts = [0u64; HIST_BUCKETS];
        for &x in v.iter() {
            counts[bucket_index(x)] += 1;
        }
        counts
    }
}

/// Point-in-time percentile view of a [`LatencyRecorder`] (seconds).
#[derive(Clone, Debug, Default)]
pub struct LatencySummary {
    /// Samples recorded.
    pub count: usize,
    /// Median latency.
    pub p50_s: f64,
    /// 95th-percentile latency.
    pub p95_s: f64,
    /// 99th-percentile latency.
    pub p99_s: f64,
    /// Mean latency.
    pub mean_s: f64,
    /// Worst observed latency.
    pub max_s: f64,
}

/// Counters for the dynamic-batching path: how well the coalescer packs
/// requests, and how often the BSB preprocessing cache spares a build.
#[derive(Default)]
pub struct BatchingCounters {
    batches: AtomicU64,
    coalesced_requests: AtomicU64,
    largest_batch: AtomicU64,
    cache_hits: AtomicU64,
    cache_misses: AtomicU64,
    cache_evictions: AtomicU64,
}

impl BatchingCounters {
    /// Record one executed batch of `size` requests (size 1 = singleton).
    pub fn record_batch(&self, size: usize) {
        self.batches.fetch_add(1, Ordering::Relaxed);
        if size > 1 {
            self.coalesced_requests.fetch_add(size as u64, Ordering::Relaxed);
        }
        self.largest_batch.fetch_max(size as u64, Ordering::Relaxed);
    }

    pub fn cache_hit(&self) {
        self.cache_hits.fetch_add(1, Ordering::Relaxed);
    }

    pub fn cache_miss(&self) {
        self.cache_misses.fetch_add(1, Ordering::Relaxed);
    }

    pub fn cache_evicted(&self, n: u64) {
        self.cache_evictions.fetch_add(n, Ordering::Relaxed);
    }

    /// Batches executed (each is one driver call; singletons count too).
    pub fn batches(&self) -> u64 {
        self.batches.load(Ordering::Relaxed)
    }

    /// Requests served through a batch of ≥ 2 members.
    pub fn coalesced_requests(&self) -> u64 {
        self.coalesced_requests.load(Ordering::Relaxed)
    }

    pub fn largest_batch(&self) -> u64 {
        self.largest_batch.load(Ordering::Relaxed)
    }

    pub fn cache_hits(&self) -> u64 {
        self.cache_hits.load(Ordering::Relaxed)
    }

    pub fn cache_misses(&self) -> u64 {
        self.cache_misses.load(Ordering::Relaxed)
    }

    pub fn cache_evictions(&self) -> u64 {
        self.cache_evictions.load(Ordering::Relaxed)
    }
}

/// Counters for the adaptive-planner path: how much traffic arrives as
/// [`Backend::Auto`](crate::kernels::Backend::Auto), which backends the
/// planner routes it to, and how many measured latencies have been fed
/// back into the cost-model calibration (the online refinement loop).
#[derive(Default)]
pub struct PlannerCounters {
    auto_requests: AtomicU64,
    observations: AtomicU64,
    invalidations: AtomicU64,
    resolved: Mutex<BTreeMap<&'static str, u64>>,
}

impl PlannerCounters {
    /// Record one `Backend::Auto` request resolved to `backend`.
    pub fn auto_resolved(&self, backend: Backend) {
        self.auto_requests.fetch_add(1, Ordering::Relaxed);
        *lock_unpoisoned(&self.resolved).entry(backend.name()).or_insert(0) +=
            1;
    }

    /// Record one measured-latency observation folded into the cost model.
    pub fn observation(&self) {
        self.observations.fetch_add(1, Ordering::Relaxed);
    }

    /// Record one profile invalidation: a graph version changed under
    /// `update_graph`, so memoised per-fingerprint routing decisions must
    /// be re-derived (the old fingerprint's profile no longer describes
    /// any servable graph).
    pub fn invalidation(&self) {
        self.invalidations.fetch_add(1, Ordering::Relaxed);
    }

    /// Requests that arrived as `Backend::Auto`.
    pub fn auto_requests(&self) -> u64 {
        self.auto_requests.load(Ordering::Relaxed)
    }

    /// Calibration observations fed back so far.
    pub fn observations(&self) -> u64 {
        self.observations.load(Ordering::Relaxed)
    }

    /// Profile invalidations recorded so far.
    pub fn invalidations(&self) -> u64 {
        self.invalidations.load(Ordering::Relaxed)
    }

    /// Memo epoch for fingerprint-keyed routing decisions: moves whenever
    /// the calibration gains an observation *or* a graph version is
    /// invalidated, so the batcher's decision memo re-resolves in either
    /// case.
    pub fn epoch(&self) -> u64 {
        self.observations().wrapping_add(self.invalidations())
    }

    /// Per-backend resolution counts, `(backend name, requests)`, sorted
    /// by name.
    pub fn resolved_counts(&self) -> Vec<(&'static str, u64)> {
        lock_unpoisoned(&self.resolved).iter().map(|(&k, &v)| (k, v)).collect()
    }
}

/// Counters for the failure-recovery machinery (DESIGN.md §11): how often
/// the coordinator caught a panic, retried, walked the degradation ladder,
/// shed on deadline, or quarantined a `(fingerprint, backend)` pair.  The
/// chaos suite reconciles these against the installed
/// [`FaultPlan`](crate::fault::FaultPlan)'s injection log.
#[derive(Default)]
pub struct FaultCounters {
    panics_caught: AtomicU64,
    retries: AtomicU64,
    fallbacks: AtomicU64,
    deadline_sheds: AtomicU64,
    quarantines: AtomicU64,
}

impl FaultCounters {
    /// A worker/executor panic converted to a structured `AttnError`.
    pub fn panic_caught(&self) {
        self.panics_caught.fetch_add(1, Ordering::Relaxed);
    }

    /// A failed prepare/execute attempted a second time.
    pub fn retry(&self) {
        self.retries.fetch_add(1, Ordering::Relaxed);
    }

    /// A request degraded: re-routed to another backend, or a merged batch
    /// split into singleton execution after a batch-level failure.
    pub fn fallback(&self) {
        self.fallbacks.fetch_add(1, Ordering::Relaxed);
    }

    /// A request shed with `DeadlineExceeded` before execution.
    pub fn deadline_shed(&self) {
        self.deadline_sheds.fetch_add(1, Ordering::Relaxed);
    }

    /// A `(fingerprint, backend)` pair quarantined after retry exhaustion.
    pub fn quarantine(&self) {
        self.quarantines.fetch_add(1, Ordering::Relaxed);
    }

    pub fn panics_caught_count(&self) -> u64 {
        self.panics_caught.load(Ordering::Relaxed)
    }

    pub fn retries(&self) -> u64 {
        self.retries.load(Ordering::Relaxed)
    }

    pub fn fallbacks(&self) -> u64 {
        self.fallbacks.load(Ordering::Relaxed)
    }

    pub fn deadline_sheds(&self) -> u64 {
        self.deadline_sheds.load(Ordering::Relaxed)
    }

    pub fn quarantines(&self) -> u64 {
        self.quarantines.load(Ordering::Relaxed)
    }

    /// Whether any recovery event has been recorded (gates the report
    /// line, keeping fault-free serving logs byte-identical to previous
    /// releases).
    pub fn any(&self) -> bool {
        self.panics_caught_count() > 0
            || self.retries() > 0
            || self.fallbacks() > 0
            || self.deadline_sheds() > 0
            || self.quarantines() > 0
    }
}

/// Counters for the partition-parallel path: how many batches ran
/// sharded (graphs above `max_plan_nodes`), how many shards they spanned,
/// and how many replicated K/V rows their halo gathers staged.
#[derive(Default)]
pub struct ShardingCounters {
    sharded_batches: AtomicU64,
    shards: AtomicU64,
    halo_rows: AtomicU64,
}

impl ShardingCounters {
    /// Record one sharded batch spanning `shards` shards with `halo_rows`
    /// replicated K/V rows gathered.
    pub fn record_batch(&self, shards: usize, halo_rows: usize) {
        self.sharded_batches.fetch_add(1, Ordering::Relaxed);
        self.shards.fetch_add(shards as u64, Ordering::Relaxed);
        self.halo_rows.fetch_add(halo_rows as u64, Ordering::Relaxed);
    }

    /// Batches that executed through a [`ShardedPlan`].
    ///
    /// [`ShardedPlan`]: crate::shard::ShardedPlan
    pub fn sharded_batches(&self) -> u64 {
        self.sharded_batches.load(Ordering::Relaxed)
    }

    /// Shards executed across all sharded batches.
    pub fn shards_executed(&self) -> u64 {
        self.shards.load(Ordering::Relaxed)
    }

    /// Replicated K/V rows gathered across all sharded batches.
    pub fn halo_rows_gathered(&self) -> u64 {
        self.halo_rows.load(Ordering::Relaxed)
    }
}

/// Counters for the network serving layer (`crate::net`): connection and
/// session lifecycle, the fingerprint handshake's upload/reuse split, and
/// raw wire volume.  Sessions update these through the coordinator's
/// shared [`Metrics`], so `report()` shows the wire front end and the
/// batching core side by side.
#[derive(Default)]
pub struct NetCounters {
    connections: AtomicU64,
    auth_failures: AtomicU64,
    protocol_errors: AtomicU64,
    requests: AtomicU64,
    graph_uploads: AtomicU64,
    graph_reuses: AtomicU64,
    bytes_in: AtomicU64,
    bytes_out: AtomicU64,
}

impl NetCounters {
    /// A connection was accepted (pre-handshake).
    pub fn connection(&self) {
        self.connections.fetch_add(1, Ordering::Relaxed);
    }

    /// A handshake presented a bad or missing auth token.
    pub fn auth_failure(&self) {
        self.auth_failures.fetch_add(1, Ordering::Relaxed);
    }

    /// A session hit a framing/decode violation and closed.
    pub fn protocol_error(&self) {
        self.protocol_errors.fetch_add(1, Ordering::Relaxed);
    }

    /// A submit was admitted into the coordinator from the wire.
    pub fn request(&self) {
        self.requests.fetch_add(1, Ordering::Relaxed);
    }

    /// A submit carried its CSR inline (uploaded topology bytes).
    pub fn graph_upload(&self) {
        self.graph_uploads.fetch_add(1, Ordering::Relaxed);
    }

    /// A submit resolved by fingerprint against the resident graph store.
    pub fn graph_reuse(&self) {
        self.graph_reuses.fetch_add(1, Ordering::Relaxed);
    }

    /// `n` frame bytes read off a socket (header included).
    pub fn read(&self, n: u64) {
        self.bytes_in.fetch_add(n, Ordering::Relaxed);
    }

    /// `n` frame bytes written to a socket (header included).
    pub fn wrote(&self, n: u64) {
        self.bytes_out.fetch_add(n, Ordering::Relaxed);
    }

    pub fn connections(&self) -> u64 {
        self.connections.load(Ordering::Relaxed)
    }

    pub fn auth_failures(&self) -> u64 {
        self.auth_failures.load(Ordering::Relaxed)
    }

    pub fn protocol_errors(&self) -> u64 {
        self.protocol_errors.load(Ordering::Relaxed)
    }

    pub fn requests(&self) -> u64 {
        self.requests.load(Ordering::Relaxed)
    }

    pub fn graph_uploads(&self) -> u64 {
        self.graph_uploads.load(Ordering::Relaxed)
    }

    pub fn graph_reuses(&self) -> u64 {
        self.graph_reuses.load(Ordering::Relaxed)
    }

    pub fn bytes_in(&self) -> u64 {
        self.bytes_in.load(Ordering::Relaxed)
    }

    pub fn bytes_out(&self) -> u64 {
        self.bytes_out.load(Ordering::Relaxed)
    }

    /// Whether any wire traffic has been recorded (gates the report line,
    /// keeping in-process serving logs byte-identical to previous
    /// releases).
    pub fn any(&self) -> bool {
        self.connections() > 0
            || self.auth_failures() > 0
            || self.protocol_errors() > 0
            || self.requests() > 0
            || self.bytes_in() > 0
            || self.bytes_out() > 0
    }
}

/// Counters for the streaming-graph path
/// ([`Coordinator::update_graph`](super::Coordinator::update_graph)): how
/// many deltas were applied, how much of each rebuild the row-window
/// splice saved, and how often the incremental path had to fall back to a
/// from-scratch BSB build.
#[derive(Default)]
pub struct StreamingCounters {
    deltas_applied: AtomicU64,
    rws_dirtied: AtomicU64,
    rws_spliced: AtomicU64,
    full_rebuilds: AtomicU64,
}

impl StreamingCounters {
    /// Record one applied delta whose incremental rebuild recomputed
    /// `dirtied` row windows and spliced `spliced` from the old BSB.
    pub fn delta_applied(&self, dirtied: usize, spliced: usize) {
        self.deltas_applied.fetch_add(1, Ordering::Relaxed);
        self.rws_dirtied.fetch_add(dirtied as u64, Ordering::Relaxed);
        self.rws_spliced.fetch_add(spliced as u64, Ordering::Relaxed);
    }

    /// Record one full-rebuild fallback (no old BSB to splice from, an
    /// incompatible shape, or a panic inside the incremental rebuild).
    pub fn full_rebuild(&self) {
        self.full_rebuilds.fetch_add(1, Ordering::Relaxed);
    }

    /// Deltas applied through `update_graph`.
    pub fn deltas_applied(&self) -> u64 {
        self.deltas_applied.load(Ordering::Relaxed)
    }

    /// Row windows recomputed across all applied deltas.
    pub fn rws_dirtied(&self) -> u64 {
        self.rws_dirtied.load(Ordering::Relaxed)
    }

    /// Row windows spliced verbatim across all applied deltas.
    pub fn rws_spliced(&self) -> u64 {
        self.rws_spliced.load(Ordering::Relaxed)
    }

    /// Incremental rebuilds that fell back to a from-scratch build.
    pub fn full_rebuilds(&self) -> u64 {
        self.full_rebuilds.load(Ordering::Relaxed)
    }

    /// Whether any streaming update has been recorded (gates the report
    /// line, keeping static-topology serving logs byte-identical to
    /// previous releases).
    pub fn any(&self) -> bool {
        self.deltas_applied() > 0 || self.full_rebuilds() > 0
    }
}

/// Aggregate serving metrics over a run.
pub struct Metrics {
    /// End-to-end request latency (admission → response, queueing
    /// included).
    pub latency: LatencyRecorder,
    /// Per-batch preprocessing time (merge + BSB build + bucket plan).
    pub preprocess: LatencyRecorder,
    /// Per-batch kernel execution time.
    pub execute: LatencyRecorder,
    /// Coalescing and plan-cache counters.
    pub batching: BatchingCounters,
    /// `Backend::Auto` routing and refinement counters.
    pub planner: PlannerCounters,
    /// Partition-parallel (sharded) execution counters.
    pub sharding: ShardingCounters,
    /// Failure-recovery counters (panic isolation, retry/fallback ladder,
    /// deadline shedding, quarantine).
    pub faults: FaultCounters,
    /// Network front-end counters (`crate::net`): sessions, handshake,
    /// wire volume.
    pub net: NetCounters,
    /// Streaming-graph counters (`update_graph`): applied deltas, dirty
    /// vs spliced row windows, full-rebuild fallbacks.
    pub streaming: StreamingCounters,
    started: Instant,
    completed: AtomicU64,
    failed: AtomicU64,
}

impl Default for Metrics {
    fn default() -> Self {
        Metrics {
            latency: LatencyRecorder::new(),
            preprocess: LatencyRecorder::new(),
            execute: LatencyRecorder::new(),
            batching: BatchingCounters::default(),
            planner: PlannerCounters::default(),
            sharding: ShardingCounters::default(),
            faults: FaultCounters::default(),
            net: NetCounters::default(),
            streaming: StreamingCounters::default(),
            started: Instant::now(),
            completed: AtomicU64::new(0),
            failed: AtomicU64::new(0),
        }
    }
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one finished request (success or failure).  Lock-free: this
    /// sits on the per-request hot path alongside the latency recorders.
    pub fn request_done(&self, ok: bool) {
        if ok {
            self.completed.fetch_add(1, Ordering::Relaxed);
        } else {
            self.failed.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Requests completed successfully.
    pub fn completed(&self) -> u64 {
        self.completed.load(Ordering::Relaxed)
    }

    /// Requests that finished with an error response.
    pub fn failed(&self) -> u64 {
        self.failed.load(Ordering::Relaxed)
    }

    /// Completed requests per second since construction.
    pub fn throughput_rps(&self) -> f64 {
        let elapsed = self.started.elapsed().as_secs_f64();
        if elapsed == 0.0 {
            0.0
        } else {
            self.completed() as f64 / elapsed
        }
    }

    /// Full structured snapshot of every counter group plus latency
    /// distributions, as a [`Json`] tree.  Unlike [`report`](Self::report)
    /// — whose conditional sections keep old logs byte-identical — every
    /// section is always present here (zeroed when idle), so consumers
    /// (`repro metrics --connect`, the serve example's breakdown table)
    /// never have to probe for keys.  Serialised over the wire as the
    /// `MetricsReport` message (DESIGN.md §15).
    pub fn to_json(&self) -> Json {
        fn stage(r: &LatencyRecorder) -> Json {
            let sum = r.snapshot();
            let hist = r.histogram();
            obj(vec![
                ("count", num(sum.count as f64)),
                ("p50_s", num(sum.p50_s)),
                ("p95_s", num(sum.p95_s)),
                ("p99_s", num(sum.p99_s)),
                ("mean_s", num(sum.mean_s)),
                ("max_s", num(sum.max_s)),
                (
                    "histogram_floors_s",
                    arr((0..HIST_BUCKETS).map(|i| num(bucket_floor_s(i))).collect()),
                ),
                (
                    "histogram_counts",
                    arr(hist.iter().map(|&c| num(c as f64)).collect()),
                ),
            ])
        }
        let b = &self.batching;
        let p = &self.planner;
        let sh = &self.sharding;
        let f = &self.faults;
        let n = &self.net;
        let st = &self.streaming;
        let resolved: Vec<(&str, Json)> = p
            .resolved_counts()
            .into_iter()
            .map(|(name, count)| (name, num(count as f64)))
            .collect();
        obj(vec![
            (
                "requests",
                obj(vec![
                    ("completed", num(self.completed() as f64)),
                    ("failed", num(self.failed() as f64)),
                    ("uptime_s", num(self.started.elapsed().as_secs_f64())),
                    ("throughput_rps", num(self.throughput_rps())),
                ]),
            ),
            ("latency", stage(&self.latency)),
            ("preprocess", stage(&self.preprocess)),
            ("execute", stage(&self.execute)),
            (
                "batching",
                obj(vec![
                    ("batches", num(b.batches() as f64)),
                    ("coalesced_requests", num(b.coalesced_requests() as f64)),
                    ("largest_batch", num(b.largest_batch() as f64)),
                    ("cache_hits", num(b.cache_hits() as f64)),
                    ("cache_misses", num(b.cache_misses() as f64)),
                    ("cache_evictions", num(b.cache_evictions() as f64)),
                ]),
            ),
            (
                "planner",
                obj(vec![
                    ("auto_requests", num(p.auto_requests() as f64)),
                    ("observations", num(p.observations() as f64)),
                    ("invalidations", num(p.invalidations() as f64)),
                    ("resolved", obj(resolved)),
                ]),
            ),
            (
                "sharding",
                obj(vec![
                    ("sharded_batches", num(sh.sharded_batches() as f64)),
                    ("shards_executed", num(sh.shards_executed() as f64)),
                    ("halo_rows_gathered", num(sh.halo_rows_gathered() as f64)),
                ]),
            ),
            (
                "faults",
                obj(vec![
                    ("panics_caught", num(f.panics_caught_count() as f64)),
                    ("retries", num(f.retries() as f64)),
                    ("fallbacks", num(f.fallbacks() as f64)),
                    ("deadline_sheds", num(f.deadline_sheds() as f64)),
                    ("quarantines", num(f.quarantines() as f64)),
                ]),
            ),
            (
                "net",
                obj(vec![
                    ("connections", num(n.connections() as f64)),
                    ("auth_failures", num(n.auth_failures() as f64)),
                    ("protocol_errors", num(n.protocol_errors() as f64)),
                    ("requests", num(n.requests() as f64)),
                    ("graph_uploads", num(n.graph_uploads() as f64)),
                    ("graph_reuses", num(n.graph_reuses() as f64)),
                    ("bytes_in", num(n.bytes_in() as f64)),
                    ("bytes_out", num(n.bytes_out() as f64)),
                ]),
            ),
            (
                "streaming",
                obj(vec![
                    ("deltas_applied", num(st.deltas_applied() as f64)),
                    ("rws_dirtied", num(st.rws_dirtied() as f64)),
                    ("rws_spliced", num(st.rws_spliced() as f64)),
                    ("full_rebuilds", num(st.full_rebuilds() as f64)),
                ]),
            ),
        ])
    }

    pub fn report(&self) -> String {
        let l = self.latency.snapshot();
        let b = &self.batching;
        let mut line = format!(
            "requests={} failed={} throughput={:.2} req/s  \
             latency p50={:.2}ms p95={:.2}ms p99={:.2}ms max={:.2}ms  \
             batches={} coalesced={} largest={}  \
             bsb-cache hit/miss/evict={}/{}/{}",
            self.completed(),
            self.failed(),
            self.throughput_rps(),
            l.p50_s * 1e3,
            l.p95_s * 1e3,
            l.p99_s * 1e3,
            l.max_s * 1e3,
            b.batches(),
            b.coalesced_requests(),
            b.largest_batch(),
            b.cache_hits(),
            b.cache_misses(),
            b.cache_evictions(),
        );
        // The planner line only appears once auto traffic exists, keeping
        // fixed-backend serving logs byte-identical to previous releases.
        let p = &self.planner;
        if p.auto_requests() > 0 {
            let routed: Vec<String> = p
                .resolved_counts()
                .into_iter()
                .map(|(name, count)| format!("{name}={count}"))
                .collect();
            line.push_str(&format!(
                "  planner auto={} obs={} [{}]",
                p.auto_requests(),
                p.observations(),
                routed.join(" "),
            ));
        }
        // Likewise the sharding line only appears once a graph actually
        // routed through the partition-parallel path.
        let sh = &self.sharding;
        if sh.sharded_batches() > 0 {
            line.push_str(&format!(
                "  sharding batches={} shards={} halo_rows={}",
                sh.sharded_batches(),
                sh.shards_executed(),
                sh.halo_rows_gathered(),
            ));
        }
        // And the faults line only appears once recovery machinery has
        // actually engaged.
        let f = &self.faults;
        if f.any() {
            line.push_str(&format!(
                "  faults panics={} retries={} fallbacks={} sheds={} \
                 quarantines={}",
                f.panics_caught_count(),
                f.retries(),
                f.fallbacks(),
                f.deadline_sheds(),
                f.quarantines(),
            ));
        }
        // The streaming line only appears once a graph delta has actually
        // flowed through `update_graph`.
        let st = &self.streaming;
        if st.any() {
            line.push_str(&format!(
                "  streaming deltas={} dirty_rws={} spliced_rws={} \
                 full_rebuilds={}",
                st.deltas_applied(),
                st.rws_dirtied(),
                st.rws_spliced(),
                st.full_rebuilds(),
            ));
        }
        // And the net line only appears when the coordinator is fronted by
        // the TCP serving layer and traffic actually flowed.
        let n = &self.net;
        if n.any() {
            line.push_str(&format!(
                "  net conns={} requests={} uploads={} reuses={} \
                 in={}B out={}B auth_fail={} proto_err={}",
                n.connections(),
                n.requests(),
                n.graph_uploads(),
                n.graph_reuses(),
                n.bytes_in(),
                n.bytes_out(),
                n.auth_failures(),
                n.protocol_errors(),
            ));
        }
        line
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn latency_percentiles() {
        let r = LatencyRecorder::new();
        for i in 1..=100 {
            r.record(i as f64 / 1000.0);
        }
        let s = r.snapshot();
        assert_eq!(s.count, 100);
        assert!((s.p50_s - 0.0505).abs() < 1e-3);
        assert!(s.p99_s > 0.098 && s.p99_s <= 0.1);
        assert_eq!(s.max_s, 0.1);
    }

    #[test]
    fn counters() {
        let m = Metrics::new();
        m.request_done(true);
        m.request_done(true);
        m.request_done(false);
        assert_eq!(m.completed(), 2);
        assert_eq!(m.failed(), 1);
        assert!(m.report().contains("requests=2"));
    }

    #[test]
    fn batching_counters() {
        let m = Metrics::new();
        m.batching.record_batch(1);
        m.batching.record_batch(5);
        m.batching.record_batch(3);
        assert_eq!(m.batching.batches(), 3);
        assert_eq!(m.batching.coalesced_requests(), 8);
        assert_eq!(m.batching.largest_batch(), 5);
        m.batching.cache_hit();
        m.batching.cache_miss();
        m.batching.cache_miss();
        m.batching.cache_evicted(2);
        assert_eq!(m.batching.cache_hits(), 1);
        assert_eq!(m.batching.cache_misses(), 2);
        assert_eq!(m.batching.cache_evictions(), 2);
        assert!(m.report().contains("largest=5"));
        assert!(m.report().contains("hit/miss/evict=1/2/2"));
    }

    #[test]
    fn sharding_counters() {
        let m = Metrics::new();
        // No sharded traffic: the report keeps the old shape.
        assert!(!m.report().contains("sharding"));
        m.sharding.record_batch(4, 120);
        m.sharding.record_batch(2, 30);
        assert_eq!(m.sharding.sharded_batches(), 2);
        assert_eq!(m.sharding.shards_executed(), 6);
        assert_eq!(m.sharding.halo_rows_gathered(), 150);
        let r = m.report();
        assert!(r.contains("sharding batches=2 shards=6 halo_rows=150"), "{r}");
    }

    #[test]
    fn fault_counters() {
        let m = Metrics::new();
        // No recovery events: the report keeps the old shape.
        assert!(!m.report().contains("faults"));
        assert!(!m.faults.any());
        m.faults.panic_caught();
        m.faults.retry();
        m.faults.retry();
        m.faults.fallback();
        m.faults.deadline_shed();
        m.faults.quarantine();
        assert_eq!(m.faults.panics_caught_count(), 1);
        assert_eq!(m.faults.retries(), 2);
        assert_eq!(m.faults.fallbacks(), 1);
        assert_eq!(m.faults.deadline_sheds(), 1);
        assert_eq!(m.faults.quarantines(), 1);
        let r = m.report();
        assert!(
            r.contains(
                "faults panics=1 retries=2 fallbacks=1 sheds=1 quarantines=1"
            ),
            "{r}"
        );
    }

    #[test]
    fn net_counters() {
        let m = Metrics::new();
        // No wire traffic: the report keeps the old shape.
        assert!(!m.report().contains("net "));
        assert!(!m.net.any());
        m.net.connection();
        m.net.request();
        m.net.request();
        m.net.graph_upload();
        m.net.graph_reuse();
        m.net.read(100);
        m.net.read(50);
        m.net.wrote(80);
        m.net.auth_failure();
        m.net.protocol_error();
        assert_eq!(m.net.connections(), 1);
        assert_eq!(m.net.requests(), 2);
        assert_eq!(m.net.graph_uploads(), 1);
        assert_eq!(m.net.graph_reuses(), 1);
        assert_eq!(m.net.bytes_in(), 150);
        assert_eq!(m.net.bytes_out(), 80);
        assert_eq!(m.net.auth_failures(), 1);
        assert_eq!(m.net.protocol_errors(), 1);
        let r = m.report();
        assert!(
            r.contains(
                "net conns=1 requests=2 uploads=1 reuses=1 in=150B \
                 out=80B auth_fail=1 proto_err=1"
            ),
            "{r}"
        );
    }

    #[test]
    fn streaming_counters() {
        let m = Metrics::new();
        // No streaming traffic: the report keeps the old shape.
        assert!(!m.report().contains("streaming"));
        assert!(!m.streaming.any());
        m.streaming.delta_applied(3, 29);
        m.streaming.delta_applied(1, 31);
        m.streaming.full_rebuild();
        assert_eq!(m.streaming.deltas_applied(), 2);
        assert_eq!(m.streaming.rws_dirtied(), 4);
        assert_eq!(m.streaming.rws_spliced(), 60);
        assert_eq!(m.streaming.full_rebuilds(), 1);
        let r = m.report();
        assert!(
            r.contains(
                "streaming deltas=2 dirty_rws=4 spliced_rws=60 full_rebuilds=1"
            ),
            "{r}"
        );
    }

    #[test]
    fn planner_epoch_moves_on_invalidation() {
        let m = Metrics::new();
        let e0 = m.planner.epoch();
        m.planner.observation();
        assert_eq!(m.planner.epoch(), e0 + 1);
        m.planner.invalidation();
        assert_eq!(m.planner.epoch(), e0 + 2);
        assert_eq!(m.planner.invalidations(), 1);
    }

    #[test]
    fn histogram_bucket_boundaries_are_closed_form() {
        // Bucket 0 floor is exactly 0; every later floor is 1e-6 · 2^(i-1).
        assert_eq!(bucket_floor_s(0), 0.0);
        for i in 1..HIST_BUCKETS {
            assert_eq!(bucket_floor_s(i), 1e-6 * f64::powi(2.0, i as i32 - 1));
        }
        // Floors are strictly increasing and each floor lands in its own
        // bucket (intervals are closed below, open above).
        for i in 1..HIST_BUCKETS {
            assert!(bucket_floor_s(i) > bucket_floor_s(i - 1));
            assert_eq!(bucket_index(bucket_floor_s(i)), i);
        }
        // Just below a floor falls in the previous bucket.
        for i in 2..HIST_BUCKETS {
            assert_eq!(bucket_index(bucket_floor_s(i) * (1.0 - 1e-12)), i - 1);
        }
        // Totality: junk and extremes never panic or escape the range.
        assert_eq!(bucket_index(f64::NAN), 0);
        assert_eq!(bucket_index(-1.0), 0);
        assert_eq!(bucket_index(0.0), 0);
        assert_eq!(bucket_index(1e-6), 0); // at-or-below 1 µs
        assert_eq!(bucket_index(1e9), HIST_BUCKETS - 1);
    }

    #[test]
    fn histogram_counts_samples() {
        let r = LatencyRecorder::new();
        r.record(0.0); // bucket 0
        r.record(1.5e-6); // bucket 1
        r.record(3e-6); // bucket 2
        r.record(3.5e-6); // bucket 2
        r.record(1e9); // top bucket
        let h = r.histogram();
        assert_eq!(h[0], 1);
        assert_eq!(h[1], 1);
        assert_eq!(h[2], 2);
        assert_eq!(h[HIST_BUCKETS - 1], 1);
        assert_eq!(h.iter().sum::<u64>(), 5);
    }

    #[test]
    fn nan_latency_sample_does_not_panic_snapshot() {
        let r = LatencyRecorder::new();
        r.record(0.5);
        r.record(f64::NAN);
        r.record(0.25);
        let s = r.snapshot();
        assert_eq!(s.count, 3);
    }

    #[test]
    fn planner_counters() {
        let m = Metrics::new();
        // No auto traffic: the report stays planner-free (old log shape).
        assert!(!m.report().contains("planner"));
        m.planner.auto_resolved(Backend::Fused3S);
        m.planner.auto_resolved(Backend::Fused3S);
        m.planner.auto_resolved(Backend::CpuCsr);
        m.planner.observation();
        assert_eq!(m.planner.auto_requests(), 3);
        assert_eq!(m.planner.observations(), 1);
        assert_eq!(
            m.planner.resolved_counts(),
            vec![("cpu_csr", 1), ("fused3s", 2)]
        );
        let r = m.report();
        assert!(r.contains("planner auto=3 obs=1"), "{r}");
        assert!(r.contains("fused3s=2"), "{r}");
    }
}
