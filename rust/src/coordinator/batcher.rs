//! Dynamic request coalescing — the admission queue in front of the
//! preprocessing workers.
//!
//! The paper's batched-graph workload (§4.1, Fig. 6) wins precisely when
//! thousands of small graphs are fused into one block-diagonal adjacency;
//! serving one tiny molecule graph per kernel call pays full BSB-build and
//! pipeline latency per request.  The [`Coalescer`] groups compatible
//! pending requests — same feature dim, scale, and backend — and flushes a
//! group as one unit of work when it reaches `max_batch_nodes` total nodes,
//! `max_batch_requests` members, or its oldest member has waited
//! `max_batch_delay`.
//!
//! The struct is pure (no threads, no clocks of its own — callers pass
//! `Instant`s in), so the size/deadline policy is unit-tested directly;
//! the server wraps it in a single batcher thread between the bounded
//! ingress queue and the preprocessing pool.

use std::collections::HashMap;
use std::time::{Duration, Instant};

use crate::kernels::Backend;

use super::request::AttnRequest;

/// Coalescing knobs (mirrored as flat fields on `CoordinatorConfig`).
#[derive(Clone, Copy, Debug)]
pub(crate) struct BatchPolicy {
    /// Max requests per batch; 1 disables coalescing entirely.
    pub max_batch_requests: usize,
    /// Flush a group once its total head-weighted node count (Σ n × heads)
    /// reaches this; requests at least this large are never coalesced
    /// (they fill a batch alone).
    pub max_batch_nodes: usize,
    /// Max time the first request of a group waits for company.
    pub max_batch_delay: Duration,
    /// Graphs above this node count take the partition-parallel (sharded)
    /// path downstream; they always run alone — merging one into a
    /// block-diagonal batch would drag its batchmates through the sharded
    /// path's halo overhead.
    pub max_plan_nodes: usize,
}

/// A request admitted into the coalescing queue, carrying its submit-time
/// stamp so the reported latency includes both the time spent queued in
/// the bounded ingress and the time spent waiting for batch company (the
/// group's flush deadline also counts from this stamp).
pub(crate) struct Admitted {
    pub req: AttnRequest,
    pub arrived: Instant,
    /// Absolute shed point: `arrived + req.deadline`.  A parked request
    /// past this instant is dropped from its group and answered with
    /// [`AttnError::DeadlineExceeded`](crate::kernels::AttnError) instead
    /// of riding a flush.  `None` = no deadline, never sheds.
    pub expires: Option<Instant>,
    /// When `req.backend` was originally [`Backend::Auto`], the cost cells
    /// the planner priced the resolved backend at (`Decision::cells`) —
    /// carried along so a singleton batch needs no second profiling pass;
    /// the executor feeds such batches' measured latencies back into the
    /// cost model.  `None` for explicitly-routed requests.
    pub auto_cells: Option<f64>,
}

impl Admitted {
    fn new(req: AttnRequest, arrived: Instant, auto_cells: Option<f64>) -> Admitted {
        let expires = req.deadline.map(|d| arrived + d);
        Admitted { req, arrived, expires, auto_cells }
    }

    /// Whether this request's deadline has passed as of `now`.
    pub fn expired(&self, now: Instant) -> bool {
        self.expires.map_or(false, |e| e <= now)
    }
}

/// One flushed unit of work: 1..N requests sharing (d, scale, backend).
pub(crate) type Flush = Vec<Admitted>;

/// Requests may only merge when the block-diagonal run is exactly the
/// per-request computation: same feature dims, head count and scale (one
/// merged `AttentionBatch`) and same backend (one plan).
#[derive(Clone, Copy, PartialEq, Eq, Hash)]
struct GroupKey {
    d: usize,
    dv: usize,
    heads: usize,
    scale_bits: u32,
    backend: Backend,
}

struct Group {
    entries: Vec<Admitted>,
    nodes: usize,
    deadline: Instant,
}

pub(crate) struct Coalescer {
    policy: BatchPolicy,
    groups: HashMap<GroupKey, Group>,
}

impl Coalescer {
    pub fn new(policy: BatchPolicy) -> Coalescer {
        Coalescer { policy, groups: HashMap::new() }
    }

    /// A request's contribution to the batch-size budget: graph nodes
    /// weighted by heads, since the merged feature buffers, the engine's
    /// work-item count and the execute time all scale with `n × heads`.
    fn weight(req: &AttnRequest) -> usize {
        req.graph.n * req.heads.max(1)
    }

    /// Whether a request is a coalescing candidate at all.  The dense
    /// fallback pads to fixed compiled sizes, so block-diagonal merging
    /// changes its cost model — it always runs alone.  Likewise a graph
    /// above `max_plan_nodes` is destined for the sharded path and never
    /// merges.
    fn coalescible(&self, req: &AttnRequest) -> bool {
        self.policy.max_batch_requests > 1
            && req.backend != Backend::Dense
            && Self::weight(req) < self.policy.max_batch_nodes
            && req.graph.n <= self.policy.max_plan_nodes
    }

    /// Admit one request.  Returns the batches this admission flushed:
    /// a singleton passthrough for non-coalescible requests, a full group
    /// when the size caps trip, or nothing (request parked until its
    /// group's deadline or capacity flush).
    ///
    /// `req.backend` must already be concrete: the batcher resolves
    /// [`Backend::Auto`] *before* admission (passing the decision's cost
    /// cells as `auto_cells`), so auto-routed requests group — and later
    /// hit the plan cache — under the resolved backend key.
    pub fn admit(
        &mut self,
        req: AttnRequest,
        now: Instant,
        auto_cells: Option<f64>,
    ) -> Vec<Flush> {
        debug_assert_ne!(req.backend, Backend::Auto, "resolve before admit");
        if !self.coalescible(&req) {
            return vec![vec![Admitted::new(req, now, auto_cells)]];
        }
        let key = GroupKey {
            d: req.d,
            dv: req.dv,
            heads: req.heads,
            scale_bits: req.scale.to_bits(),
            backend: req.backend,
        };
        let mut flushed = Vec::new();
        // A merged batch must stay a single-plan graph: if this admission
        // would push the group past the sharding threshold, flush the
        // group first, so a coalesced block-diagonal graph never routes
        // through the sharded path its members individually avoid.
        // (Weight over-counts nodes by the head factor — conservative.)
        let would_cross = self.groups.get(&key).map_or(false, |g| {
            g.nodes.saturating_add(Self::weight(&req))
                > self.policy.max_plan_nodes
        });
        if would_cross {
            // invariant: would_cross is only true when get(&key) was Some.
            let group = self.groups.remove(&key).expect("group present");
            flushed.push(group.entries);
        }
        let group = self.groups.entry(key).or_insert_with(|| Group {
            entries: Vec::new(),
            nodes: 0,
            deadline: now + self.policy.max_batch_delay,
        });
        group.nodes += Self::weight(&req);
        group.entries.push(Admitted::new(req, now, auto_cells));
        if group.nodes >= self.policy.max_batch_nodes
            || group.entries.len() >= self.policy.max_batch_requests
        {
            // invariant: entry() above guarantees the key is present.
            let group = self.groups.remove(&key).expect("group present");
            flushed.push(group.entries);
        }
        flushed
    }

    /// Earliest instant at which the batcher must wake: the soonest group
    /// flush deadline or the soonest parked request expiry, whichever
    /// comes first (None when nothing is parked).
    pub fn next_deadline(&self) -> Option<Instant> {
        self.groups
            .values()
            .flat_map(|g| {
                std::iter::once(g.deadline)
                    .chain(g.entries.iter().filter_map(|a| a.expires))
            })
            .min()
    }

    /// Remove every parked request whose deadline has passed and return
    /// them so the caller can answer each with `DeadlineExceeded`.  Group
    /// node budgets are re-credited and emptied groups dropped, so a
    /// group kept alive only by expired members stops holding a flush
    /// deadline open.
    pub fn shed_expired(&mut self, now: Instant) -> Vec<Admitted> {
        let mut shed = Vec::new();
        self.groups.retain(|_, g| {
            let mut kept = Vec::with_capacity(g.entries.len());
            for a in g.entries.drain(..) {
                if a.expired(now) {
                    g.nodes -= Self::weight(&a.req);
                    shed.push(a);
                } else {
                    kept.push(a);
                }
            }
            g.entries = kept;
            !g.entries.is_empty()
        });
        shed
    }

    /// Flush every group whose delay budget has elapsed.
    pub fn flush_due(&mut self, now: Instant) -> Vec<Flush> {
        let due: Vec<GroupKey> = self
            .groups
            .iter()
            .filter(|(_, g)| g.deadline <= now)
            .map(|(k, _)| *k)
            .collect();
        due.into_iter()
            // invariant: keys were just collected from the live map and the
            // map is not touched in between.
            .map(|k| self.groups.remove(&k).expect("group present").entries)
            .collect()
    }

    /// Drain everything unconditionally (the shutdown path: no request that
    /// was admitted may be dropped).
    pub fn flush_all(&mut self) -> Vec<Flush> {
        self.groups.drain().map(|(_, g)| g.entries).collect()
    }

    /// Requests currently parked in the coalescing queue.
    pub fn pending(&self) -> usize {
        self.groups.values().map(|g| g.entries.len()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;
    use std::sync::mpsc::channel;

    fn policy(reqs: usize, nodes: usize, delay_ms: u64) -> BatchPolicy {
        BatchPolicy {
            max_batch_requests: reqs,
            max_batch_nodes: nodes,
            max_batch_delay: Duration::from_millis(delay_ms),
            max_plan_nodes: usize::MAX,
        }
    }

    fn req(id: u64, n: usize, d: usize, scale: f32, backend: Backend) -> AttnRequest {
        let (tx, _rx) = channel();
        AttnRequest::single_head(
            id,
            generators::ring(n),
            d,
            vec![0.0; n * d],
            vec![0.0; n * d],
            vec![0.0; n * d],
            scale,
            backend,
            tx,
        )
    }

    fn req_heads(id: u64, n: usize, d: usize, heads: usize) -> AttnRequest {
        let (tx, _rx) = channel();
        AttnRequest {
            id,
            graph: generators::ring(n),
            d,
            dv: d,
            heads,
            q: vec![0.0; heads * n * d],
            k: vec![0.0; heads * n * d],
            v: vec![0.0; heads * n * d],
            scale: 1.0,
            backend: Backend::Fused3S,
            deadline: None,
            span: 0,
            reply: tx,
        }
    }

    fn req_deadline(id: u64, n: usize, deadline: Duration) -> AttnRequest {
        AttnRequest { deadline: Some(deadline), ..req(id, n, 4, 1.0, Backend::Fused3S) }
    }

    #[test]
    fn request_cap_flushes_full_group() {
        let mut co = Coalescer::new(policy(3, 10_000, 100));
        let now = Instant::now();
        assert!(co.admit(req(0, 8, 4, 1.0, Backend::Fused3S), now, None).is_empty());
        assert!(co.admit(req(1, 8, 4, 1.0, Backend::Fused3S), now, None).is_empty());
        let flushed = co.admit(req(2, 8, 4, 1.0, Backend::Fused3S), now, None);
        assert_eq!(flushed.len(), 1);
        let ids: Vec<u64> = flushed[0].iter().map(|a| a.req.id).collect();
        assert_eq!(ids, vec![0, 1, 2]);
        assert_eq!(co.pending(), 0);
    }

    #[test]
    fn node_cap_flushes_group() {
        let mut co = Coalescer::new(policy(100, 20, 100));
        let now = Instant::now();
        assert!(co.admit(req(0, 8, 4, 1.0, Backend::Fused3S), now, None).is_empty());
        let flushed = co.admit(req(1, 12, 4, 1.0, Backend::Fused3S), now, None);
        assert_eq!(flushed.len(), 1);
        assert_eq!(flushed[0].len(), 2);
    }

    #[test]
    fn incompatible_requests_do_not_mix() {
        let mut co = Coalescer::new(policy(2, 10_000, 100));
        let now = Instant::now();
        assert!(co.admit(req(0, 8, 4, 1.0, Backend::Fused3S), now, None).is_empty());
        // Different d, different scale, different backend: three new groups.
        assert!(co.admit(req(1, 8, 8, 1.0, Backend::Fused3S), now, None).is_empty());
        assert!(co.admit(req(2, 8, 4, 0.5, Backend::Fused3S), now, None).is_empty());
        assert!(co.admit(req(3, 8, 4, 1.0, Backend::CpuCsr), now, None).is_empty());
        assert_eq!(co.pending(), 4);
        // A matching partner flushes only its own group.
        let flushed = co.admit(req(4, 8, 4, 1.0, Backend::Fused3S), now, None);
        assert_eq!(flushed.len(), 1);
        let ids: Vec<u64> = flushed[0].iter().map(|a| a.req.id).collect();
        assert_eq!(ids, vec![0, 4]);
        assert_eq!(co.pending(), 3);
    }

    #[test]
    fn node_budget_is_head_weighted() {
        // Budget 100: two 4-head ring(16) requests weigh 64 each, so the
        // second admission trips the cap (128 ≥ 100) where two single-head
        // requests of the same graphs (weight 16) would keep parking.
        let mut co = Coalescer::new(policy(100, 100, 100));
        let now = Instant::now();
        assert!(co.admit(req_heads(0, 16, 4, 4), now, None).is_empty());
        let flushed = co.admit(req_heads(1, 16, 4, 4), now, None);
        assert_eq!(flushed.len(), 1);
        assert_eq!(flushed[0].len(), 2);
        // And a single request at weight ≥ budget runs alone outright.
        let f = co.admit(req_heads(2, 32, 4, 4), now, None);
        assert_eq!(f.len(), 1);
        assert_eq!(f[0].len(), 1);
        assert_eq!(co.pending(), 0);
    }

    #[test]
    fn head_counts_do_not_mix() {
        let mut co = Coalescer::new(policy(2, 10_000, 100));
        let now = Instant::now();
        assert!(co.admit(req_heads(0, 8, 4, 1), now, None).is_empty());
        // Same d/scale/backend but different heads: a new group.
        assert!(co.admit(req_heads(1, 8, 4, 4), now, None).is_empty());
        assert_eq!(co.pending(), 2);
        // A matching 4-head partner flushes only the 4-head group.
        let flushed = co.admit(req_heads(2, 8, 4, 4), now, None);
        assert_eq!(flushed.len(), 1);
        let ids: Vec<u64> = flushed[0].iter().map(|a| a.req.id).collect();
        assert_eq!(ids, vec![1, 2]);
        assert_eq!(co.pending(), 1);
    }

    #[test]
    fn dense_and_oversize_pass_through() {
        let mut co = Coalescer::new(policy(8, 32, 100));
        let now = Instant::now();
        let f = co.admit(req(0, 8, 4, 1.0, Backend::Dense), now, None);
        assert_eq!(f.len(), 1);
        assert_eq!(f[0].len(), 1);
        // A request at/above max_batch_nodes runs alone.
        let f = co.admit(req(1, 40, 4, 1.0, Backend::Fused3S), now, None);
        assert_eq!(f.len(), 1);
        assert_eq!(f[0].len(), 1);
        assert_eq!(co.pending(), 0);
    }

    #[test]
    fn sharded_size_requests_run_alone() {
        // max_plan_nodes 32: a ring(64) request is sharding-bound and must
        // pass straight through even though it fits the batch-node budget.
        let mut co = Coalescer::new(BatchPolicy {
            max_batch_requests: 8,
            max_batch_nodes: 10_000,
            max_batch_delay: Duration::from_millis(100),
            max_plan_nodes: 32,
        });
        let now = Instant::now();
        let f = co.admit(req(0, 64, 4, 1.0, Backend::Fused3S), now, None);
        assert_eq!(f.len(), 1);
        assert_eq!(f[0].len(), 1);
        // Small requests still coalesce.
        assert!(co.admit(req(1, 8, 4, 1.0, Backend::Fused3S), now, None).is_empty());
        assert_eq!(co.pending(), 1);
    }

    #[test]
    fn merged_batches_stay_under_the_sharding_threshold() {
        // Each request (24 nodes) is below max_plan_nodes = 40, but two of
        // them merged would cross it: the second admission must flush the
        // first request alone instead of forming a 48-node merged graph
        // that would route through the sharded path.
        let mut co = Coalescer::new(BatchPolicy {
            max_batch_requests: 8,
            max_batch_nodes: 10_000,
            max_batch_delay: Duration::from_millis(100),
            max_plan_nodes: 40,
        });
        let now = Instant::now();
        assert!(co.admit(req(0, 24, 4, 1.0, Backend::Fused3S), now, None).is_empty());
        let f = co.admit(req(1, 24, 4, 1.0, Backend::Fused3S), now, None);
        assert_eq!(f.len(), 1, "prior group flushed before admission");
        let ids: Vec<u64> = f[0].iter().map(|a| a.req.id).collect();
        assert_eq!(ids, vec![0]);
        // The new request parked in a fresh group.
        assert_eq!(co.pending(), 1);
        // Well under the threshold, requests still merge as before.
        let f = co.admit(req(2, 12, 4, 1.0, Backend::Fused3S), now, None);
        assert!(f.is_empty());
        assert_eq!(co.pending(), 2);
    }

    #[test]
    fn deadline_flushes_only_due_groups() {
        let mut co = Coalescer::new(policy(10, 10_000, 5));
        let t0 = Instant::now();
        assert!(co.admit(req(0, 8, 4, 1.0, Backend::Fused3S), t0, None).is_empty());
        let t1 = t0 + Duration::from_millis(3);
        assert!(co.admit(req(1, 8, 8, 1.0, Backend::Fused3S), t1, None).is_empty());
        assert_eq!(co.next_deadline(), Some(t0 + Duration::from_millis(5)));
        // At t0+5ms only the first group is due.
        let due = co.flush_due(t0 + Duration::from_millis(5));
        assert_eq!(due.len(), 1);
        assert_eq!(due[0][0].req.id, 0);
        assert_eq!(co.pending(), 1);
        // Well past both deadlines, the second flushes too.
        let due = co.flush_due(t1 + Duration::from_millis(5));
        assert_eq!(due.len(), 1);
        assert_eq!(due[0][0].req.id, 1);
        assert_eq!(co.next_deadline(), None);
    }

    #[test]
    fn shed_expired_drops_only_expired_members() {
        let mut co = Coalescer::new(policy(10, 10_000, 1000));
        let t0 = Instant::now();
        assert!(co.admit(req_deadline(0, 8, Duration::from_millis(5)), t0, None).is_empty());
        assert!(co.admit(req(1, 8, 4, 1.0, Backend::Fused3S), t0, None).is_empty());
        assert_eq!(co.pending(), 2);
        // Before the deadline nothing sheds.
        assert!(co.shed_expired(t0).is_empty());
        // Past it, only the deadlined member is shed; its batchmate stays.
        let shed = co.shed_expired(t0 + Duration::from_millis(5));
        assert_eq!(shed.len(), 1);
        assert_eq!(shed[0].req.id, 0);
        assert_eq!(co.pending(), 1);
        // The survivor still flushes normally.
        let all = co.flush_all();
        assert_eq!(all.len(), 1);
        assert_eq!(all[0][0].req.id, 1);
    }

    #[test]
    fn shed_expired_drops_emptied_groups_and_recredits_budget() {
        // Node budget 20: after shedding the expired 12-node member, an
        // 8-node + 8-node pair must still park (16 < 20) — proof the
        // expired member's weight was re-credited rather than leaked.
        let mut co = Coalescer::new(policy(10, 20, 1000));
        let t0 = Instant::now();
        assert!(co.admit(req_deadline(0, 12, Duration::from_millis(1)), t0, None).is_empty());
        let t1 = t0 + Duration::from_millis(2);
        let shed = co.shed_expired(t1);
        assert_eq!(shed.len(), 1);
        assert_eq!(co.pending(), 0);
        assert_eq!(co.next_deadline(), None, "emptied group dropped");
        assert!(co.admit(req(1, 8, 4, 1.0, Backend::Fused3S), t1, None).is_empty());
        assert!(co.admit(req(2, 8, 4, 1.0, Backend::Fused3S), t1, None).is_empty());
        assert_eq!(co.pending(), 2);
    }

    #[test]
    fn next_deadline_includes_member_expiries() {
        // Group flush deadline is t0+1000ms but the member expires at
        // t0+10ms: the batcher must wake for the expiry, not the flush.
        let mut co = Coalescer::new(policy(10, 10_000, 1000));
        let t0 = Instant::now();
        assert!(co.admit(req_deadline(0, 8, Duration::from_millis(10)), t0, None).is_empty());
        assert_eq!(co.next_deadline(), Some(t0 + Duration::from_millis(10)));
    }

    #[test]
    fn flush_all_drains() {
        let mut co = Coalescer::new(policy(10, 10_000, 1000));
        let now = Instant::now();
        for i in 0..4 {
            assert!(co
                .admit(req(i, 8, 4 + (i as usize % 2) * 4, 1.0, Backend::Fused3S), now, None)
                .is_empty());
        }
        assert_eq!(co.pending(), 4);
        let all = co.flush_all();
        let total: usize = all.iter().map(|f| f.len()).sum();
        assert_eq!(total, 4);
        assert_eq!(co.pending(), 0);
    }

    #[test]
    fn coalescing_disabled_passes_everything_through() {
        let mut co = Coalescer::new(policy(1, 10_000, 100));
        let now = Instant::now();
        for i in 0..3 {
            let f = co.admit(req(i, 8, 4, 1.0, Backend::Fused3S), now, None);
            assert_eq!(f.len(), 1);
            assert_eq!(f[0].len(), 1);
        }
        assert_eq!(co.pending(), 0);
    }
}
