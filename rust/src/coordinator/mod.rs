//! The serving coordinator — Layer 3's request path.
//!
//! Architecture (std threads + mpsc; tokio is unavailable offline):
//!
//! ```text
//!  clients ── submit ──► ingress queue
//!                            │
//!              preprocessing workers (BSB build + bucket plan, CPU-bound,
//!              scales with cores; the paper's "preprocessing alongside
//!              sparse matrix compaction")
//!                            │
//!                     executor thread (owns the PJRT Runtime; dispatches
//!                     bucketed kernel calls in reordered schedule order)
//!                            │
//!  clients ◄── response channels ──┘
//! ```
//!
//! Python never appears anywhere in this path; the executor replays AOT
//! artifacts only.

pub mod metrics;
pub mod request;
pub mod server;

pub use metrics::{LatencyRecorder, Metrics};
pub use request::{AttnRequest, AttnResponse};
pub use server::{Coordinator, CoordinatorConfig};
