//! The serving coordinator — Layer 3's request path.
//!
//! Architecture (std threads + mpsc; tokio is unavailable offline):
//!
//! ```text
//!  clients ── submit ──► bounded ingress queue (backpressure: blocks)
//!                            │
//!                     batcher thread (resolves Backend::Auto through the
//!                     adaptive planner — the sharded cost candidate for
//!                     graphs above max_plan_nodes — then dynamic request
//!                     coalescing: groups compatible small-graph requests
//!                     into block-diagonal batches by size/deadline policy —
//!                     paper §4.1's batched-graph workload, applied to
//!                     serving; sharding-bound graphs always run alone)
//!                            │
//!              preprocessing workers (merge components, fingerprint-keyed
//!              BSB cache, BSB build + bucket plan on cache miss; graphs
//!              above max_plan_nodes become ShardedPlans whose per-shard
//!              plans cache by shard-local fingerprint; the paper's
//!              "preprocessing alongside sparse matrix compaction")
//!                            │
//!                     executor thread (owns the PJRT Runtime — or the
//!                     offline host emulation — one fused driver call per
//!                     batch, per-component scatter of the output rows)
//!                            │
//!  clients ◄── response channels ──┘
//! ```
//!
//! Python never appears anywhere in this path; the executor replays AOT
//! artifacts only (or, under `ExecutorKind::HostEmulation`, the CPU
//! emulation of the fused call — which is how the differential batching
//! tests and the stress suite run the full path with no artifacts).
//!
//! The executor additionally closes the adaptive-planner loop: batches
//! whose backend was chosen by the planner
//! ([`Backend::Auto`](crate::kernels::Backend::Auto)) report their
//! measured kernel latency back into the cost-model calibration, which
//! can be persisted across restarts via
//! [`CoordinatorConfig::calibration_path`].
//!
//! Every stage is fault-isolated (DESIGN.md §11): panics in admission,
//! preparation, or execution are caught and answered as structured
//! [`AttnError`](crate::kernels::AttnError)s; prepare/execute failures
//! walk a retry → quarantine ([`Quarantine`]) → re-resolve → singleton-
//! split degradation ladder; deadlined requests are shed at every
//! queueing point; and the fault counters surface in
//! [`Metrics::report`](metrics::Metrics::report).

mod batcher;
mod cache;
pub mod metrics;
pub mod recover;
pub mod request;
pub mod server;

pub use cache::DriverCache;
pub use metrics::{
    BatchingCounters, FaultCounters, LatencyRecorder, Metrics, NetCounters,
    PlannerCounters, ShardingCounters, StreamingCounters,
};
pub use recover::Quarantine;
pub use request::{AttnRequest, AttnResponse};
pub use server::{Coordinator, CoordinatorConfig, ExecutorKind, UpdateReport};
