//! Compressed-sparse-row adjacency — the input format of the whole stack
//! (matching what DGL/PyG hand to the paper's kernel).

use anyhow::{bail, Result};

/// A directed graph / sparse 0-1 matrix in CSR form.
///
/// `indptr.len() == n + 1`; row i's column indices are
/// `indices[indptr[i]..indptr[i+1]]`, sorted ascending and deduplicated.
#[derive(Clone, Debug, PartialEq)]
pub struct CsrGraph {
    pub n: usize,
    pub indptr: Vec<u32>,
    pub indices: Vec<u32>,
}

impl CsrGraph {
    /// Build from an edge list (duplicates and self-loops allowed; edges are
    /// sorted and deduplicated).  Counting sort over rows: O(n + m).
    pub fn from_edges(n: usize, edges: &[(u32, u32)]) -> Result<CsrGraph> {
        for &(u, v) in edges {
            if u as usize >= n || v as usize >= n {
                bail!("edge ({u},{v}) out of range for n={n}");
            }
        }
        let mut counts = vec![0u32; n + 1];
        for &(u, _) in edges {
            counts[u as usize + 1] += 1;
        }
        for i in 0..n {
            counts[i + 1] += counts[i];
        }
        let mut indices = vec![0u32; edges.len()];
        let mut cursor = counts.clone();
        for &(u, v) in edges {
            let c = &mut cursor[u as usize];
            indices[*c as usize] = v;
            *c += 1;
        }
        // Sort + dedup each row.
        let mut indptr = vec![0u32; n + 1];
        let mut w = 0usize;
        let mut dedup = Vec::new();
        for i in 0..n {
            let (s, e) = (counts[i] as usize, counts[i + 1] as usize);
            dedup.clear();
            dedup.extend_from_slice(&indices[s..e]);
            dedup.sort_unstable();
            dedup.dedup();
            // Write back compacted.
            for (k, &v) in dedup.iter().enumerate() {
                indices[w + k] = v;
            }
            w += dedup.len();
            indptr[i + 1] = w as u32;
        }
        indices.truncate(w);
        Ok(CsrGraph { n, indptr, indices })
    }

    /// Number of stored edges (nonzeros).
    pub fn nnz(&self) -> usize {
        self.indices.len()
    }

    /// Content fingerprint (FNV-1a over n + indptr + indices).  Two graphs
    /// with equal structure always collide; unequal graphs collide with
    /// ~2⁻⁶⁴ probability — good enough to key the coordinator's BSB
    /// preprocessing cache, which additionally cross-checks the graph's
    /// node and edge counts on every hit so a mismatched collision only
    /// costs a rebuild.
    pub fn fingerprint(&self) -> u64 {
        let mut h = fnv1a_mix(0xcbf2_9ce4_8422_2325, self.n as u64);
        for &p in &self.indptr {
            h = fnv1a_mix(h, p as u64);
        }
        for &c in &self.indices {
            h = fnv1a_mix(h, c as u64);
        }
        h
    }

    /// Column indices of row i.
    #[inline]
    pub fn row(&self, i: usize) -> &[u32] {
        &self.indices[self.indptr[i] as usize..self.indptr[i + 1] as usize]
    }

    /// Out-degree of row i.
    #[inline]
    pub fn degree(&self, i: usize) -> usize {
        (self.indptr[i + 1] - self.indptr[i]) as usize
    }

    pub fn degrees(&self) -> Vec<usize> {
        (0..self.n).map(|i| self.degree(i)).collect()
    }

    pub fn avg_degree(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.nnz() as f64 / self.n as f64
        }
    }

    pub fn max_degree(&self) -> usize {
        (0..self.n).map(|i| self.degree(i)).max().unwrap_or(0)
    }

    /// True if (u, v) is an edge (binary search within the row).
    pub fn has_edge(&self, u: usize, v: u32) -> bool {
        self.row(u).binary_search(&v).is_ok()
    }

    /// Add a self-loop on every node (the GNN convention; AGNN's Eq. 3
    /// explicitly includes them).
    pub fn with_self_loops(&self) -> CsrGraph {
        let mut edges = Vec::with_capacity(self.nnz() + self.n);
        for i in 0..self.n {
            edges.push((i as u32, i as u32));
            for &j in self.row(i) {
                edges.push((i as u32, j));
            }
        }
        CsrGraph::from_edges(self.n, &edges).expect("in-range edges")
    }

    /// Make the adjacency symmetric (A ∪ Aᵀ) — undirected-graph convention.
    pub fn symmetrized(&self) -> CsrGraph {
        let mut edges = Vec::with_capacity(2 * self.nnz());
        for i in 0..self.n {
            for &j in self.row(i) {
                edges.push((i as u32, j));
                edges.push((j, i as u32));
            }
        }
        CsrGraph::from_edges(self.n, &edges).expect("in-range edges")
    }

    pub fn is_symmetric(&self) -> bool {
        for i in 0..self.n {
            for &j in self.row(i) {
                if !self.has_edge(j as usize, i as u32) {
                    return false;
                }
            }
        }
        true
    }

    /// Dense 0/1 mask (for oracle checks on small graphs only).
    pub fn to_dense_mask(&self) -> Vec<i32> {
        assert!(self.n <= 4096, "dense mask only for small graphs");
        let mut m = vec![0i32; self.n * self.n];
        for i in 0..self.n {
            for &j in self.row(i) {
                m[i * self.n + j as usize] = 1;
            }
        }
        m
    }

    /// Relabel nodes: node i becomes perm[i].  `perm` must be a permutation.
    pub fn permuted(&self, perm: &[u32]) -> CsrGraph {
        assert_eq!(perm.len(), self.n);
        let mut edges = Vec::with_capacity(self.nnz());
        for i in 0..self.n {
            for &j in self.row(i) {
                edges.push((perm[i], perm[j as usize]));
            }
        }
        CsrGraph::from_edges(self.n, &edges).expect("permutation in range")
    }
}

/// FNV-1a over one u64 value, byte by byte.
fn fnv1a_mix(mut h: u64, v: u64) -> u64 {
    for byte in v.to_le_bytes() {
        h = (h ^ byte as u64).wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> CsrGraph {
        // 0 -> 1,2 ; 1 -> 2 ; 2 -> 0 ; 3 isolated
        CsrGraph::from_edges(4, &[(0, 1), (0, 2), (1, 2), (2, 0)]).unwrap()
    }

    #[test]
    fn from_edges_basic() {
        let g = tiny();
        assert_eq!(g.n, 4);
        assert_eq!(g.nnz(), 4);
        assert_eq!(g.row(0), &[1, 2]);
        assert_eq!(g.row(1), &[2]);
        assert_eq!(g.row(3), &[] as &[u32]);
        assert_eq!(g.degree(0), 2);
    }

    #[test]
    fn dedup_and_sort() {
        let g = CsrGraph::from_edges(3, &[(0, 2), (0, 1), (0, 2), (0, 1)]).unwrap();
        assert_eq!(g.row(0), &[1, 2]);
        assert_eq!(g.nnz(), 2);
    }

    #[test]
    fn out_of_range_rejected() {
        assert!(CsrGraph::from_edges(2, &[(0, 5)]).is_err());
    }

    #[test]
    fn self_loops() {
        let g = tiny().with_self_loops();
        for i in 0..4 {
            assert!(g.has_edge(i, i as u32));
        }
        assert_eq!(g.nnz(), 8);
        // idempotent-ish: adding again doesn't duplicate
        assert_eq!(g.with_self_loops().nnz(), 8);
    }

    #[test]
    fn symmetrize() {
        let g = tiny().symmetrized();
        assert!(g.is_symmetric());
        assert!(g.has_edge(1, 0));
        assert!(g.has_edge(2, 1));
    }

    #[test]
    fn dense_mask_roundtrip() {
        let g = tiny();
        let m = g.to_dense_mask();
        for i in 0..4 {
            for j in 0..4 {
                assert_eq!(m[i * 4 + j] == 1, g.has_edge(i, j as u32));
            }
        }
    }

    #[test]
    fn fingerprint_separates_structures() {
        let g = tiny();
        assert_eq!(g.fingerprint(), tiny().fingerprint());
        assert_eq!(g.fingerprint(), g.clone().fingerprint());
        // Any structural change moves the fingerprint.
        let extra = CsrGraph::from_edges(4, &[(0, 1), (0, 2), (1, 2), (2, 0), (3, 0)])
            .unwrap();
        assert_ne!(g.fingerprint(), extra.fingerprint());
        let bigger = CsrGraph::from_edges(5, &[(0, 1), (0, 2), (1, 2), (2, 0)])
            .unwrap();
        assert_ne!(g.fingerprint(), bigger.fingerprint());
        // Same edge multiset, different row owner: indptr must disambiguate.
        let a = CsrGraph::from_edges(3, &[(0, 1), (1, 2)]).unwrap();
        let b = CsrGraph::from_edges(3, &[(0, 1), (0, 2)]).unwrap();
        assert_ne!(a.fingerprint(), b.fingerprint());
    }

    #[test]
    fn permuted_preserves_structure() {
        let g = tiny();
        let perm = vec![2u32, 0, 3, 1];
        let p = g.permuted(&perm);
        assert_eq!(p.nnz(), g.nnz());
        for i in 0..4 {
            for &j in g.row(i) {
                assert!(p.has_edge(perm[i] as usize, perm[j as usize]));
            }
        }
    }
}
