//! The benchmark dataset suite, calibrated to the paper's Table 6.
//!
//! The paper's real datasets (SNAP / OGB / IGB downloads) are unavailable
//! offline, so each entry here is a *synthetic stand-in* generated to land in
//! the same sparsity regime after BSB compaction: matched degree scale and —
//! crucially for the load-balancing experiments — matched TCB/RW
//! irregularity (CV).  Node counts are scaled down (≈4–16×) so the full
//! suite benches in minutes on the single-core CPU-PJRT substrate; the
//! *relative* behaviour between kernels is what the experiments compare.
//!
//! `repro table6` prints the same metrics the paper reports (TCB/RW and
//! nnz/TCB, avg + CV) for this suite so the calibration is auditable.

use anyhow::{bail, Result};

use crate::util::prng::Rng;

use super::batch::{batched_dataset, BatchKind};
use super::csr::CsrGraph;
use super::generators;

/// A named benchmark graph.
pub struct Dataset {
    pub name: &'static str,
    /// The paper dataset this one is calibrated against.
    pub paper_name: &'static str,
    pub graph: CsrGraph,
    pub batched: bool,
}

fn ds(name: &'static str, paper: &'static str, g: CsrGraph) -> Dataset {
    Dataset { name, paper_name: paper, graph: g.with_self_loops(), batched: false }
}

/// Overlay a few mega-hubs on a base graph (drives TCB/RW CV towards the
/// Blog/Reddit long-tail regime of Table 7).
fn with_hubs(base: CsrGraph, hubs: usize, hub_deg: usize, seed: u64) -> CsrGraph {
    let mut rng = Rng::new(seed);
    let mut edges: Vec<(u32, u32)> = Vec::with_capacity(base.nnz() + hubs * hub_deg);
    for u in 0..base.n {
        for &v in base.row(u) {
            edges.push((u as u32, v));
        }
    }
    for h in 0..hubs {
        let hub = rng.below(base.n) as u32;
        let _ = h;
        for _ in 0..hub_deg {
            let v = rng.below(base.n) as u32;
            edges.push((hub, v));
            edges.push((v, hub));
        }
    }
    CsrGraph::from_edges(base.n, &edges).expect("in range")
}

/// The single-graph suite (paper Table 6, scaled).  Ordered by edge count
/// ascending like Fig. 5.
pub fn suite_single() -> Vec<Dataset> {
    vec![
        // Small citation graphs — kept at full scale, uniform degree.
        ds("citeseer-sim", "Citeseer", generators::erdos_renyi(3327, 2.8, 101)),
        ds("cora-sim", "Cora", generators::erdos_renyi(2708, 3.9, 102)),
        // Pubmed: uniform, low CV.
        ds("pubmed-sim", "Pubmed", generators::erdos_renyi(8192, 4.5, 103)),
        // Elliptic: extremely sparse (avg TCB/RW 2.5).
        ds("elliptic-sim", "Elliptic", generators::erdos_renyi(16384, 1.2, 104)),
        // Com-Amazon: sparse with community locality.
        ds("comamazon-sim", "Com-Amazon", generators::sbm(96, 128, 0.02, 0.00004, 105)),
        // Musae-github: power-law, CV ≈ 1.3.
        ds(
            "github-sim",
            "Musae-github",
            with_hubs(generators::barabasi_albert(8192, 6, 106), 6, 900, 206),
        ),
        // Artist: moderately dense, mild CV.
        ds("artist-sim", "Artist", generators::erdos_renyi(8192, 16.0, 107)),
        // Amazon0505: local structure, low CV.
        ds("amazon-sim", "Amazon0505", generators::sbm(128, 128, 0.06, 0.00005, 108)),
        // Blog: the highest CV in Table 6 (2.47) — BA plus strong hubs.
        ds(
            "blog-sim",
            "Blog",
            with_hubs(generators::barabasi_albert(6144, 10, 109), 10, 1800, 209),
        ),
        // IGB-small: uniform, larger.
        ds("igbsmall-sim", "IGB-small", generators::erdos_renyi(16384, 12.0, 110)),
        // Yelp: skewed communities (CV ≈ 1.3).
        ds("yelp-sim", "Yelp", generators::rmat(13, 20, 0.57, 0.19, 0.19, 111)),
        // Ogbn-products: large-ish, moderate skew.
        ds("ogbnproducts-sim", "Ogbn-products", generators::rmat(14, 16, 0.45, 0.22, 0.22, 112)),
        // AmazonProducts: the densest (most edges).
        ds("amazonproducts-sim", "AmazonProducts", generators::rmat(13, 32, 0.5, 0.2, 0.2, 113)),
        // Reddit: heavy degree + extreme tail (decile table graph).
        ds(
            "reddit-sim",
            "Reddit",
            with_hubs(generators::rmat(12, 56, 0.55, 0.2, 0.2, 114), 8, 2500, 214),
        ),
        // IGB-medium: the largest single graph we keep.
        ds("igbmedium-sim", "IGB-medium", generators::erdos_renyi(32768, 12.0, 115)),
    ]
}

/// The batched-graph suite (paper Fig. 6: LRGB + OGB, batch size 1024).
pub fn suite_batched() -> Vec<Dataset> {
    let mk = |name: &'static str,
              paper: &'static str,
              count: usize,
              lo: usize,
              hi: usize,
              seed: u64,
              kind: BatchKind| {
        let (g, _) = batched_dataset(count, lo, hi, seed, kind);
        Dataset { name, paper_name: paper, graph: g.with_self_loops(), batched: true }
    };
    vec![
        mk("molhiv-sim", "ogbg-molhiv", 1024, 10, 30, 301, BatchKind::Molecule),
        mk("molpcba-sim", "ogbg-molpcba", 1024, 14, 36, 302, BatchKind::Molecule),
        mk("peptides-func-sim", "Peptides-func", 256, 80, 220, 303, BatchKind::Peptide),
        mk("peptides-struct-sim", "Peptides-struct", 256, 80, 220, 304, BatchKind::Peptide),
    ]
}

/// Small fast suite for tests and `--quick` runs.
pub fn suite_tiny() -> Vec<Dataset> {
    vec![
        ds("tiny-er", "(test)", generators::erdos_renyi(512, 4.0, 900)),
        ds("tiny-ba", "(test)", generators::barabasi_albert(512, 4, 901)),
        ds("tiny-grid", "(test)", generators::grid2d(16, 32)),
    ]
}

/// Look up any dataset by name across all suites (generates on demand).
pub fn by_name(name: &str) -> Result<Dataset> {
    for d in suite_single()
        .into_iter()
        .chain(suite_batched())
        .chain(suite_tiny())
    {
        if d.name == name {
            return Ok(d);
        }
    }
    bail!(
        "unknown dataset '{name}' (try: {})",
        all_names().join(", ")
    )
}

pub fn all_names() -> Vec<&'static str> {
    suite_single()
        .iter()
        .map(|d| d.name)
        .chain(suite_batched().iter().map(|d| d.name))
        .chain(suite_tiny().iter().map(|d| d.name))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn suite_has_expected_entries() {
        let s = suite_single();
        assert_eq!(s.len(), 15); // Table 6 has 15 rows
        for d in &s {
            assert!(d.graph.n > 0);
            assert!(d.graph.nnz() >= d.graph.n, "{} self-loops", d.name);
        }
    }

    #[test]
    fn batched_suite() {
        let s = suite_batched();
        assert_eq!(s.len(), 4);
        assert!(s.iter().all(|d| d.batched));
    }

    #[test]
    fn lookup_by_name() {
        assert!(by_name("reddit-sim").is_ok());
        assert!(by_name("molhiv-sim").is_ok());
        assert!(by_name("nope").is_err());
    }

    #[test]
    fn datasets_deterministic() {
        let a = by_name("github-sim").unwrap();
        let b = by_name("github-sim").unwrap();
        assert_eq!(a.graph, b.graph);
    }

    #[test]
    fn irregular_graphs_have_high_degree_cv() {
        use crate::util::stats;
        let hi = by_name("blog-sim").unwrap();
        let lo = by_name("pubmed-sim").unwrap();
        let cv = |g: &CsrGraph| {
            stats::cv(&g.degrees().iter().map(|&d| d as f64).collect::<Vec<_>>())
        };
        assert!(
            cv(&hi.graph) > 3.0 * cv(&lo.graph),
            "blog {} vs pubmed {}",
            cv(&hi.graph),
            cv(&lo.graph)
        );
    }
}
