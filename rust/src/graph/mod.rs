//! Graph substrate: CSR storage, synthetic generators, batching, datasets.
//!
//! The paper evaluates on 15 real-world graphs (Table 6) plus batched
//! small-graph benchmarks (LRGB / OGB).  Those datasets are not available
//! offline, so [`datasets`] provides a *calibrated synthetic suite*: for each
//! paper dataset we generate a graph whose post-compaction sparsity metrics
//! (TCB/RW, nnz/TCB and their CVs) land in the same regime — uniform-degree
//! graphs where the paper's are uniform, power-law where the paper's are
//! power-law.  See DESIGN.md §1 substitution 2.

pub mod batch;
pub mod csr;
pub mod datasets;
pub mod delta;
pub mod generators;
pub mod io;

pub use csr::CsrGraph;
pub use delta::{DeltaReport, GraphDelta};
