//! Graph IO: whitespace edge-list text (SNAP convention) and a compact
//! binary CSR format for caching preprocessed graphs.

use std::fs;
use std::io::{BufWriter, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use super::csr::CsrGraph;

/// Read a SNAP-style edge list: one `u v` pair per line, `#` comments.
/// Node ids may be sparse; they are compacted to 0..n preserving order.
pub fn read_edge_list(path: &Path) -> Result<CsrGraph> {
    let text = fs::read_to_string(path)
        .with_context(|| format!("reading {}", path.display()))?;
    parse_edge_list(&text)
}

pub fn parse_edge_list(text: &str) -> Result<CsrGraph> {
    let mut raw_edges: Vec<(u64, u64)> = Vec::new();
    for (lineno, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') || line.starts_with('%') {
            continue;
        }
        let mut it = line.split_whitespace();
        let (u, v) = match (it.next(), it.next()) {
            (Some(u), Some(v)) => (u, v),
            _ => bail!("line {}: expected 'u v'", lineno + 1),
        };
        let u: u64 = u.parse().with_context(|| format!("line {}", lineno + 1))?;
        let v: u64 = v.parse().with_context(|| format!("line {}", lineno + 1))?;
        raw_edges.push((u, v));
    }
    // Compact ids.
    let mut ids: Vec<u64> = raw_edges
        .iter()
        .flat_map(|&(u, v)| [u, v])
        .collect();
    ids.sort_unstable();
    ids.dedup();
    let lookup = |x: u64| ids.binary_search(&x).unwrap() as u32;
    let edges: Vec<(u32, u32)> =
        raw_edges.iter().map(|&(u, v)| (lookup(u), lookup(v))).collect();
    CsrGraph::from_edges(ids.len(), &edges)
}

/// Write an edge list.
pub fn write_edge_list(g: &CsrGraph, path: &Path) -> Result<()> {
    let f = fs::File::create(path)?;
    let mut w = BufWriter::new(f);
    writeln!(w, "# fused3s edge list: n={} nnz={}", g.n, g.nnz())?;
    for u in 0..g.n {
        for &v in g.row(u) {
            writeln!(w, "{u} {v}")?;
        }
    }
    Ok(())
}

const BIN_MAGIC: &[u8; 8] = b"F3SCSR01";

/// Write the compact binary CSR (magic, n, nnz, indptr, indices; all LE u32/u64).
pub fn write_binary(g: &CsrGraph, path: &Path) -> Result<()> {
    let mut buf =
        Vec::with_capacity(24 + 4 * (g.indptr.len() + g.indices.len()));
    buf.extend_from_slice(BIN_MAGIC);
    buf.extend_from_slice(&(g.n as u64).to_le_bytes());
    buf.extend_from_slice(&(g.nnz() as u64).to_le_bytes());
    for &x in &g.indptr {
        buf.extend_from_slice(&x.to_le_bytes());
    }
    for &x in &g.indices {
        buf.extend_from_slice(&x.to_le_bytes());
    }
    fs::write(path, buf)?;
    Ok(())
}

/// Read the compact binary CSR.
pub fn read_binary(path: &Path) -> Result<CsrGraph> {
    let buf = fs::read(path)?;
    if buf.len() < 24 || &buf[..8] != BIN_MAGIC {
        bail!("{}: not a fused3s binary graph", path.display());
    }
    let n = u64::from_le_bytes(buf[8..16].try_into().unwrap()) as usize;
    let nnz = u64::from_le_bytes(buf[16..24].try_into().unwrap()) as usize;
    let need = 24 + 4 * (n + 1 + nnz);
    if buf.len() != need {
        bail!("truncated graph file: {} != {}", buf.len(), need);
    }
    let mut off = 24;
    let mut read_u32s = |count: usize| {
        let mut v = Vec::with_capacity(count);
        for _ in 0..count {
            v.push(u32::from_le_bytes(buf[off..off + 4].try_into().unwrap()));
            off += 4;
        }
        v
    };
    let indptr = read_u32s(n + 1);
    let indices = read_u32s(nnz);
    if indptr[n] as usize != nnz {
        bail!("inconsistent indptr");
    }
    Ok(CsrGraph { n, indptr, indices })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_basic() {
        let g = parse_edge_list("# comment\n0 1\n1 2\n2 0\n").unwrap();
        assert_eq!(g.n, 3);
        assert_eq!(g.nnz(), 3);
        assert!(g.has_edge(1, 2));
    }

    #[test]
    fn parse_compacts_sparse_ids() {
        let g = parse_edge_list("100 5\n5 2000\n").unwrap();
        assert_eq!(g.n, 3); // ids {5, 100, 2000} -> {0, 1, 2}
        assert!(g.has_edge(1, 0)); // 100 -> 5
        assert!(g.has_edge(0, 2)); // 5 -> 2000
    }

    #[test]
    fn parse_rejects_bad_lines() {
        assert!(parse_edge_list("0\n").is_err());
        assert!(parse_edge_list("a b\n").is_err());
    }

    #[test]
    fn text_roundtrip() {
        let g = crate::graph::generators::erdos_renyi(64, 3.0, 5);
        let dir = std::env::temp_dir().join("f3s_io_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("g.txt");
        write_edge_list(&g, &p).unwrap();
        let g2 = read_edge_list(&p).unwrap();
        // Ids compact identically when all nodes present; isolated nodes are
        // dropped by the text format, so compare edges via containment.
        for u in 0..g2.n {
            assert!(g2.degree(u) > 0 || g.degree(u) > 0);
        }
        assert_eq!(g2.nnz(), g.nnz());
    }

    #[test]
    fn binary_roundtrip_exact() {
        let g = crate::graph::generators::barabasi_albert(200, 3, 6);
        let dir = std::env::temp_dir().join("f3s_io_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("g.bin");
        write_binary(&g, &p).unwrap();
        let g2 = read_binary(&p).unwrap();
        assert_eq!(g, g2);
    }

    #[test]
    fn binary_rejects_garbage() {
        let dir = std::env::temp_dir().join("f3s_io_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("bad.bin");
        std::fs::write(&p, b"not a graph").unwrap();
        assert!(read_binary(&p).is_err());
    }
}
