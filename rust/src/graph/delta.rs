//! Batched edge deltas against a fingerprinted CSR base — the streaming
//! half of ROADMAP item 3.
//!
//! A [`GraphDelta`] carries edge inserts/removes pinned to the
//! [`fingerprint`](CsrGraph::fingerprint) of the graph it was diffed
//! against, so a patch can never be applied to the wrong version.
//! [`GraphDelta::apply`] patches the CSR **in place** and reports the
//! *dirty row windows*: the invalidation contract is per-row membership —
//! a row window is dirty iff the adjacency of at least one of its rows
//! actually changed.  (That is a refinement of "distinct column set
//! changed": a TCB bitmap encodes *which row* holds each nonzero, so an
//! insert that reuses a column another row already occupies still dirties
//! the window, while a no-op insert of an existing edge dirties nothing.)
//!
//! The patched CSR is canonical — rows sorted ascending, deduplicated,
//! `indptr` rebuilt — so its fingerprint equals a from-scratch
//! [`CsrGraph::from_edges`] recompute on the patched edge set.  That
//! equality is what lets the coordinator's `DriverCache` and the net
//! layer's `GraphStore` treat "patched" and "re-uploaded" graphs as the
//! same version.

use anyhow::{bail, Result};

use crate::graph::CsrGraph;
use crate::TCB_R;

/// A batch of edge inserts/removes against one base graph version.
///
/// Duplicates within `inserts` (or within `removes`) are tolerated and
/// collapse to one change; an edge listed in *both* is rejected by
/// [`apply`](GraphDelta::apply) as ambiguous.  Inserting an edge that is
/// already present, or removing one that is absent, is a no-op and does
/// not dirty its row window.
#[derive(Clone, Debug, PartialEq)]
pub struct GraphDelta {
    /// Fingerprint of the base graph this delta was built against.
    pub base_fp: u64,
    /// Edges to add, as (row, col) in the base graph's node space.
    pub inserts: Vec<(u32, u32)>,
    /// Edges to drop, as (row, col).
    pub removes: Vec<(u32, u32)>,
}

/// What [`GraphDelta::apply`] did: version edge, effective change counts,
/// and the exact set of row windows whose contents changed.
#[derive(Clone, Debug, PartialEq)]
pub struct DeltaReport {
    /// Fingerprint before the patch (== the delta's `base_fp`).
    pub old_fp: u64,
    /// Fingerprint after the patch (== from-scratch recompute).
    pub new_fp: u64,
    /// Edges actually added (no-op inserts excluded).
    pub inserted: usize,
    /// Edges actually dropped (no-op removes excluded).
    pub removed: usize,
    /// Sorted row-window indices whose rows changed; exactly the windows
    /// an incremental BSB rebuild must recompute.
    pub dirty_rws: Vec<u32>,
}

impl GraphDelta {
    /// A delta pinned to `base`'s current fingerprint.
    pub fn against(base: &CsrGraph, inserts: Vec<(u32, u32)>, removes: Vec<(u32, u32)>) -> GraphDelta {
        GraphDelta { base_fp: base.fingerprint(), inserts, removes }
    }

    /// The delta that turns `old` into `new` (both must share `n`).
    /// Useful for differential tests and benches; O(nnz) two-pointer row
    /// merge.
    pub fn diff(old: &CsrGraph, new: &CsrGraph) -> GraphDelta {
        assert_eq!(old.n, new.n, "diff requires equal node counts");
        let mut inserts = Vec::new();
        let mut removes = Vec::new();
        for u in 0..old.n {
            let (a, b) = (old.row(u), new.row(u));
            let (mut i, mut j) = (0usize, 0usize);
            while i < a.len() || j < b.len() {
                match (a.get(i), b.get(j)) {
                    (Some(&x), Some(&y)) if x == y => {
                        i += 1;
                        j += 1;
                    }
                    (Some(&x), Some(&y)) if x < y => {
                        removes.push((u as u32, x));
                        i += 1;
                    }
                    (Some(_), Some(&y)) => {
                        inserts.push((u as u32, y));
                        j += 1;
                    }
                    (Some(&x), None) => {
                        removes.push((u as u32, x));
                        i += 1;
                    }
                    (None, Some(&y)) => {
                        inserts.push((u as u32, y));
                        j += 1;
                    }
                    (None, None) => unreachable!(),
                }
            }
        }
        GraphDelta { base_fp: old.fingerprint(), inserts, removes }
    }

    /// True when the delta carries no edits at all.
    pub fn is_empty(&self) -> bool {
        self.inserts.is_empty() && self.removes.is_empty()
    }

    /// Total listed edits (before no-op filtering).
    pub fn len(&self) -> usize {
        self.inserts.len() + self.removes.len()
    }

    /// Validate ranges and base-version match without patching.
    pub fn check(&self, g: &CsrGraph) -> Result<()> {
        let fp = g.fingerprint();
        if fp != self.base_fp {
            bail!(
                "delta base fingerprint {:#018x} does not match graph {:#018x}",
                self.base_fp,
                fp
            );
        }
        for &(u, v) in self.inserts.iter().chain(self.removes.iter()) {
            if u as usize >= g.n || v as usize >= g.n {
                bail!("delta edge ({u},{v}) out of range for n={}", g.n);
            }
        }
        Ok(())
    }

    /// Patch `g` in place; on success the CSR is canonical (rows sorted,
    /// deduplicated) and the report's `new_fp` equals a from-scratch
    /// [`CsrGraph::from_edges`] fingerprint on the patched edge set.  On
    /// error `g` is untouched.
    pub fn apply(&self, g: &mut CsrGraph) -> Result<DeltaReport> {
        self.check(g)?;

        let mut ins = self.inserts.clone();
        ins.sort_unstable();
        ins.dedup();
        let mut rem = self.removes.clone();
        rem.sort_unstable();
        rem.dedup();

        // Ambiguity check: the same edge on both sides has no defined order.
        {
            let (mut i, mut j) = (0usize, 0usize);
            while i < ins.len() && j < rem.len() {
                match ins[i].cmp(&rem[j]) {
                    std::cmp::Ordering::Less => i += 1,
                    std::cmp::Ordering::Greater => j += 1,
                    std::cmp::Ordering::Equal => {
                        let (u, v) = ins[i];
                        bail!("edge ({u},{v}) listed as both insert and remove");
                    }
                }
            }
        }

        let old_fp = self.base_fp;
        let n = g.n;
        let grow = ins.len();
        let mut indices = Vec::with_capacity(g.indices.len() + grow);
        let mut indptr = Vec::with_capacity(n + 1);
        indptr.push(0u32);

        let (mut ii, mut ri) = (0usize, 0usize);
        let mut inserted = 0usize;
        let mut removed = 0usize;
        let mut dirty_rows: Vec<u32> = Vec::new();

        for u in 0..n {
            let row = g.row(u);
            let ins_lo = ii;
            while ii < ins.len() && ins[ii].0 as usize == u {
                ii += 1;
            }
            let rem_lo = ri;
            while ri < rem.len() && rem[ri].0 as usize == u {
                ri += 1;
            }
            let row_ins = &ins[ins_lo..ii];
            let row_rem = &rem[rem_lo..ri];

            if row_ins.is_empty() && row_rem.is_empty() {
                indices.extend_from_slice(row);
                indptr.push(indices.len() as u32);
                continue;
            }

            // Merge old ∪ inserts, skipping removes; all three inputs are
            // sorted, so one forward pass keeps the row canonical.
            let mut changed = false;
            let (mut a, mut b) = (0usize, 0usize);
            let mut r = 0usize;
            loop {
                let next_old = row.get(a).copied();
                let next_ins = (b < row_ins.len()).then(|| row_ins[b].1);
                let v = match (next_old, next_ins) {
                    (Some(x), Some(y)) if x == y => {
                        // No-op insert: edge already present.
                        a += 1;
                        b += 1;
                        x
                    }
                    (Some(x), Some(y)) if x < y => {
                        a += 1;
                        x
                    }
                    (Some(_), Some(y)) | (None, Some(y)) => {
                        b += 1;
                        inserted += 1;
                        changed = true;
                        y
                    }
                    (Some(x), None) => {
                        a += 1;
                        x
                    }
                    (None, None) => break,
                };
                // Drop v when a pending remove names it (no-op removes —
                // values never reached — simply fall off the cursor).
                while r < row_rem.len() && row_rem[r].1 < v {
                    r += 1;
                }
                if r < row_rem.len() && row_rem[r].1 == v {
                    r += 1;
                    removed += 1;
                    changed = true;
                    // An insert that re-adds a removed edge was rejected
                    // above, so a dropped v is never re-pushed.
                    continue;
                }
                indices.push(v);
            }
            if changed {
                dirty_rows.push(u as u32);
            }
            indptr.push(indices.len() as u32);
        }

        g.indptr = indptr;
        g.indices = indices;

        let mut dirty_rws: Vec<u32> =
            dirty_rows.iter().map(|&u| u / TCB_R as u32).collect();
        dirty_rws.dedup(); // rows arrive sorted, so windows do too

        Ok(DeltaReport {
            old_fp,
            new_fp: g.fingerprint(),
            inserted,
            removed,
            dirty_rws,
        })
    }

    /// Non-mutating convenience: clone, patch, return the patched graph.
    pub fn applied(&self, g: &CsrGraph) -> Result<(CsrGraph, DeltaReport)> {
        let mut patched = g.clone();
        let report = self.apply(&mut patched)?;
        Ok((patched, report))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;
    use crate::util::prng::Rng;

    fn edges_of(g: &CsrGraph) -> Vec<(u32, u32)> {
        let mut out = Vec::with_capacity(g.nnz());
        for u in 0..g.n {
            for &v in g.row(u) {
                out.push((u as u32, v));
            }
        }
        out
    }

    #[test]
    fn apply_matches_from_scratch() {
        let g0 = generators::erdos_renyi(200, 4.0, 7);
        let delta = GraphDelta::against(
            &g0,
            vec![(0, 5), (0, 6), (17, 3), (199, 0)],
            vec![edges_of(&g0)[0], edges_of(&g0)[10]],
        );
        let mut g = g0.clone();
        let report = delta.apply(&mut g).unwrap();

        let mut want = edges_of(&g0);
        want.retain(|e| !delta.removes.contains(e));
        want.extend_from_slice(&delta.inserts);
        let scratch = CsrGraph::from_edges(g0.n, &want).unwrap();
        assert_eq!(g, scratch);
        assert_eq!(report.new_fp, scratch.fingerprint());
        assert_eq!(report.old_fp, g0.fingerprint());
    }

    #[test]
    fn noop_edits_do_not_dirty() {
        let g0 = CsrGraph::from_edges(64, &[(0, 1), (20, 3), (40, 5)]).unwrap();
        // Insert an existing edge + remove an absent one: nothing changes.
        let delta = GraphDelta::against(&g0, vec![(0, 1)], vec![(40, 7)]);
        let mut g = g0.clone();
        let report = delta.apply(&mut g).unwrap();
        assert_eq!(g, g0);
        assert_eq!(report.new_fp, report.old_fp);
        assert_eq!(report.inserted, 0);
        assert_eq!(report.removed, 0);
        assert!(report.dirty_rws.is_empty());
    }

    #[test]
    fn dirty_windows_are_exact() {
        // Rows 0..16 = RW 0, 16..32 = RW 1, 32..48 = RW 2.
        let g0 = CsrGraph::from_edges(48, &[(0, 1), (17, 2), (33, 3)]).unwrap();
        let delta = GraphDelta::against(&g0, vec![(18, 9)], vec![(33, 3)]);
        let (_, report) = delta.applied(&g0).unwrap();
        assert_eq!(report.dirty_rws, vec![1, 2]);
    }

    #[test]
    fn same_column_other_row_still_dirties() {
        // Column 5 already present in RW 0 via row 0; inserting (1,5)
        // leaves the window's distinct-column set unchanged but must still
        // dirty it (the bitmap gains a bit in row 1).
        let g0 = CsrGraph::from_edges(16, &[(0, 5)]).unwrap();
        let delta = GraphDelta::against(&g0, vec![(1, 5)], vec![]);
        let (g, report) = delta.applied(&g0).unwrap();
        assert_eq!(report.dirty_rws, vec![0]);
        assert_eq!(g.row(1), &[5]);
    }

    #[test]
    fn conflicting_edit_rejected() {
        let g0 = CsrGraph::from_edges(8, &[(0, 1)]).unwrap();
        let delta = GraphDelta::against(&g0, vec![(2, 3)], vec![(2, 3)]);
        let mut g = g0.clone();
        assert!(delta.apply(&mut g).is_err());
        assert_eq!(g, g0); // untouched on error
    }

    #[test]
    fn stale_base_rejected() {
        let g0 = CsrGraph::from_edges(8, &[(0, 1)]).unwrap();
        let mut delta = GraphDelta::against(&g0, vec![(2, 3)], vec![]);
        delta.base_fp ^= 1;
        assert!(delta.apply(&mut g0.clone()).is_err());
    }

    #[test]
    fn out_of_range_rejected() {
        let g0 = CsrGraph::from_edges(8, &[(0, 1)]).unwrap();
        let delta = GraphDelta::against(&g0, vec![(2, 99)], vec![]);
        assert!(delta.apply(&mut g0.clone()).is_err());
    }

    #[test]
    fn diff_roundtrips() {
        let mut rng = Rng::new(11);
        for _ in 0..8 {
            let n = rng.range(1, 300);
            let a = generators::erdos_renyi(n, 3.0, rng.next_u64());
            let b = generators::erdos_renyi(n, 3.0, rng.next_u64());
            let delta = GraphDelta::diff(&a, &b);
            let (patched, report) = delta.applied(&a).unwrap();
            assert_eq!(patched, b);
            assert_eq!(report.new_fp, b.fingerprint());
        }
    }

    #[test]
    fn duplicate_edits_collapse() {
        let g0 = CsrGraph::from_edges(8, &[(0, 1)]).unwrap();
        let delta =
            GraphDelta::against(&g0, vec![(2, 3), (2, 3), (2, 3)], vec![(0, 1), (0, 1)]);
        let (g, report) = delta.applied(&g0).unwrap();
        assert_eq!(report.inserted, 1);
        assert_eq!(report.removed, 1);
        assert_eq!(g.row(2), &[3]);
        assert_eq!(g.row(0), &[] as &[u32]);
    }
}
