//! Graph batching — the LRGB / OGB small-graph workload (paper §4.1, Fig. 6).
//!
//! Graph-property-prediction datasets contain thousands of small graphs
//! (molecules, ASTs, peptides: ~20–500 nodes).  Frameworks batch them into
//! one block-diagonal adjacency so a single kernel launch covers the whole
//! batch; the resulting sparsity pattern — many disconnected components with
//! tight locality — is what Fig. 6 measures.

use crate::util::prng::Rng;

use super::csr::CsrGraph;

/// Block-diagonal concatenation of many graphs.  Returns the batched graph
/// plus each component's node offset (the last entry is the total).
pub fn batch_graphs(graphs: &[CsrGraph]) -> (CsrGraph, Vec<u32>) {
    let refs: Vec<&CsrGraph> = graphs.iter().collect();
    batch_graph_refs(&refs)
}

/// [`batch_graphs`] over borrowed components — the coordinator's coalescing
/// path batches requests it does not own contiguously.
///
/// Zero graphs (and components with `n == 0`) are well-defined: the result
/// is the empty graph with `offsets == [0, …]`, never a panic.
pub fn batch_graph_refs(graphs: &[&CsrGraph]) -> (CsrGraph, Vec<u32>) {
    let total: usize = graphs.iter().map(|g| g.n).sum();
    let total_nnz: usize = graphs.iter().map(|g| g.nnz()).sum();
    let mut offsets = Vec::with_capacity(graphs.len() + 1);
    if total == 0 {
        // Guard the degenerate cases (no graphs, or all empty) explicitly
        // so callers get a structurally valid empty batch.
        offsets.resize(graphs.len() + 1, 0u32);
        let empty = CsrGraph { n: 0, indptr: vec![0], indices: Vec::new() };
        return (empty, offsets);
    }
    let mut edges = Vec::with_capacity(total_nnz);
    let mut base = 0u32;
    for g in graphs {
        offsets.push(base);
        for u in 0..g.n {
            for &v in g.row(u) {
                edges.push((base + u as u32, base + v));
            }
        }
        base += g.n as u32;
    }
    offsets.push(base);
    // Component edges are disjoint and already deduplicated, so the batch
    // must hold exactly the preallocated nnz sum — a mismatch means a
    // component's CSR invariants are broken.
    debug_assert_eq!(edges.len(), total_nnz, "batch edge count != Σ nnz");
    let batched = CsrGraph::from_edges(total, &edges).expect("offsets in range");
    debug_assert_eq!(batched.nnz(), total_nnz, "batching must not dedup edges");
    (batched, offsets)
}

/// A random "molecule-like" graph: a spanning tree plus a few ring-closing
/// edges, degree mostly 1–4 (the OGB molhiv regime).
pub fn random_molecule(n: usize, rng: &mut Rng) -> CsrGraph {
    assert!(n >= 2);
    let mut edges = Vec::with_capacity(2 * (n + n / 6));
    // Random tree: attach node i to a uniform previous node with locality
    // bias (chains with branches, like molecular backbones).
    for i in 1..n {
        let lo = i.saturating_sub(6);
        let p = rng.range(lo, i);
        edges.push((i as u32, p as u32));
        edges.push((p as u32, i as u32));
    }
    // Ring closures.
    for _ in 0..n / 6 {
        let a = rng.below(n);
        let b = rng.below(n);
        if a != b {
            edges.push((a as u32, b as u32));
            edges.push((b as u32, a as u32));
        }
    }
    CsrGraph::from_edges(n, &edges).expect("in range")
}

/// A "peptide-like" graph (LRGB regime): a long backbone chain with short
/// side branches — larger and more path-like than molecules.
pub fn random_peptide(n: usize, rng: &mut Rng) -> CsrGraph {
    assert!(n >= 4);
    let backbone = (n * 3) / 4;
    let mut edges = Vec::with_capacity(2 * n);
    for i in 1..backbone {
        edges.push((i as u32, (i - 1) as u32));
        edges.push(((i - 1) as u32, i as u32));
    }
    for i in backbone..n {
        let anchor = rng.below(backbone);
        edges.push((i as u32, anchor as u32));
        edges.push((anchor as u32, i as u32));
    }
    CsrGraph::from_edges(n, &edges).expect("in range")
}

/// Build a batched dataset of `count` small graphs with sizes uniform in
/// `[min_n, max_n]`, using the given per-graph generator.
pub fn batched_dataset(
    count: usize,
    min_n: usize,
    max_n: usize,
    seed: u64,
    kind: BatchKind,
) -> (CsrGraph, Vec<u32>) {
    let mut rng = Rng::new(seed);
    let graphs: Vec<CsrGraph> = (0..count)
        .map(|_| {
            let n = rng.range(min_n, max_n + 1);
            match kind {
                BatchKind::Molecule => random_molecule(n, &mut rng),
                BatchKind::Peptide => random_peptide(n, &mut rng),
            }
        })
        .collect();
    batch_graphs(&graphs)
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BatchKind {
    Molecule,
    Peptide,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn batch_is_block_diagonal() {
        let g1 = super::super::generators::ring(8);
        let g2 = super::super::generators::star(5);
        let (b, off) = batch_graphs(&[g1.clone(), g2.clone()]);
        assert_eq!(b.n, 13);
        assert_eq!(off, vec![0, 8, 13]);
        assert_eq!(b.nnz(), g1.nnz() + g2.nnz());
        // No cross-component edges.
        for u in 0..8 {
            for &v in b.row(u) {
                assert!(v < 8);
            }
        }
        for u in 8..13 {
            for &v in b.row(u) {
                assert!(v >= 8);
            }
        }
        // Component structure preserved.
        assert_eq!(b.degree(8), 4); // star hub
    }

    #[test]
    fn zero_and_empty_graphs_guarded() {
        // No graphs at all.
        let (b, off) = batch_graphs(&[]);
        assert_eq!(b.n, 0);
        assert_eq!(b.nnz(), 0);
        assert_eq!(off, vec![0]);
        // All-empty components.
        let empty = CsrGraph { n: 0, indptr: vec![0], indices: Vec::new() };
        let (b, off) = batch_graphs(&[empty.clone(), empty.clone()]);
        assert_eq!(b.n, 0);
        assert_eq!(off, vec![0, 0, 0]);
        // An empty component sandwiched between real ones.
        let ring = super::super::generators::ring(8);
        let (b, off) = batch_graphs(&[ring.clone(), empty, ring.clone()]);
        assert_eq!(b.n, 16);
        assert_eq!(off, vec![0, 8, 8, 16]);
        assert_eq!(b.nnz(), 2 * ring.nnz());
    }

    #[test]
    fn refs_variant_matches_owned() {
        let g1 = super::super::generators::ring(8);
        let g2 = super::super::generators::star(5);
        let owned = batch_graphs(&[g1.clone(), g2.clone()]);
        let refs = batch_graph_refs(&[&g1, &g2]);
        assert_eq!(owned, refs);
    }

    #[test]
    fn molecule_connected_and_sparse() {
        let mut rng = Rng::new(3);
        let g = random_molecule(30, &mut rng);
        assert_eq!(g.n, 30);
        assert!(g.avg_degree() < 5.0);
        assert!(g.is_symmetric());
        // Tree edges guarantee connectivity: BFS reaches all nodes.
        let mut seen = vec![false; g.n];
        let mut stack = vec![0usize];
        seen[0] = true;
        while let Some(u) = stack.pop() {
            for &v in g.row(u) {
                if !seen[v as usize] {
                    seen[v as usize] = true;
                    stack.push(v as usize);
                }
            }
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn peptide_is_path_like() {
        let mut rng = Rng::new(4);
        let g = random_peptide(100, &mut rng);
        // Most nodes degree <= 3 (chain + occasional branch anchor).
        let low = g.degrees().iter().filter(|&&d| d <= 3).count();
        assert!(low as f64 > 0.85 * g.n as f64);
    }

    #[test]
    fn batched_dataset_deterministic() {
        let (a, _) = batched_dataset(32, 10, 40, 9, BatchKind::Molecule);
        let (b, _) = batched_dataset(32, 10, 40, 9, BatchKind::Molecule);
        assert_eq!(a, b);
    }
}
