//! Synthetic graph generators spanning the sparsity regimes of Table 6.
//!
//! * [`erdos_renyi`] — uniform degree, low CV (Pubmed/Cora-like).
//! * [`barabasi_albert`] — power-law tail, high TCB/RW CV (Github/Blog-like).
//! * [`power_law`] — Chung–Lu preferential weights with a *tunable*
//!   exponent (the shard-imbalance workload: hubs at low node ids).
//! * [`rmat`] — skewed Kronecker-style communities (Reddit/Yelp-like).
//! * [`grid2d`], [`star`], [`ring`] — structured corner cases for tests.
//! * [`sbm`] — stochastic block model (clustered, batched-graph-like).
//!
//! All generators are deterministic in the seed and return graphs with
//! sorted, deduplicated CSR rows.

use crate::util::prng::Rng;

use super::csr::CsrGraph;

/// G(n, avg_deg): each node draws ~avg_deg uniform out-neighbours.
pub fn erdos_renyi(n: usize, avg_deg: f64, seed: u64) -> CsrGraph {
    let mut rng = Rng::new(seed);
    let mut edges = Vec::with_capacity((n as f64 * avg_deg) as usize);
    for u in 0..n {
        // Poisson-ish: deterministic floor + Bernoulli remainder.
        let base = avg_deg.floor() as usize;
        let extra = rng.coin(avg_deg - avg_deg.floor());
        let deg = base + usize::from(extra);
        for _ in 0..deg {
            edges.push((u as u32, rng.below(n) as u32));
        }
    }
    CsrGraph::from_edges(n, &edges).expect("generated edges in range")
}

/// Barabási–Albert preferential attachment: each new node attaches m edges
/// to existing nodes with probability proportional to degree.  Produces the
/// power-law degree distribution behind the paper's high-CV datasets.
pub fn barabasi_albert(n: usize, m: usize, seed: u64) -> CsrGraph {
    assert!(n > m && m >= 1);
    let mut rng = Rng::new(seed);
    // Repeated-nodes list trick: sampling uniformly from `targets` is
    // degree-proportional sampling.
    let mut targets: Vec<u32> = (0..m as u32).collect();
    let mut edges: Vec<(u32, u32)> = Vec::with_capacity(2 * n * m);
    for u in m..n {
        let mut chosen = Vec::with_capacity(m);
        while chosen.len() < m {
            let t = targets[rng.below(targets.len())];
            if t != u as u32 && !chosen.contains(&t) {
                chosen.push(t);
            }
        }
        for &t in &chosen {
            edges.push((u as u32, t));
            edges.push((t, u as u32));
            targets.push(t);
            targets.push(u as u32);
        }
    }
    CsrGraph::from_edges(n, &edges).expect("generated edges in range")
}

/// Chung–Lu-style power-law graph with a **tunable degree exponent**:
/// node i carries weight `(i+1)^(-1/(alpha-1))` and each of the
/// `n·avg_deg/2` undirected edges picks both endpoints
/// weight-proportionally, so expected degrees follow `p(deg) ~ deg^-alpha`.
/// Smaller `alpha` (→ 2) concentrates edges onto ever-heavier hubs.
///
/// This is the shard-imbalance workload: unlike [`star`] (one hub, every
/// other row trivial) or [`barabasi_albert`] (exponent pinned at ~3 by the
/// attachment process), the exponent knob dials the hub skew — and hence
/// the TCB-work imbalance a row-window partitioner must absorb —
/// continuously.  Low-id nodes are the hubs, so contiguous row partitions
/// are maximally skewed (the adversarial case for `Strategy::Contiguous`).
pub fn power_law(n: usize, avg_deg: f64, alpha: f64, seed: u64) -> CsrGraph {
    assert!(n >= 2, "need at least two nodes");
    assert!(alpha > 2.0, "degree exponent must exceed 2 (finite mean)");
    let gamma = 1.0 / (alpha - 1.0);
    // Cumulative weights for inverse-transform endpoint sampling.
    let mut cum: Vec<f64> = Vec::with_capacity(n);
    let mut acc = 0.0f64;
    for i in 0..n {
        acc += ((i + 1) as f64).powf(-gamma);
        cum.push(acc);
    }
    let total = acc;
    let mut rng = Rng::new(seed);
    let m = (n as f64 * avg_deg / 2.0).round() as usize;
    let mut edges = Vec::with_capacity(2 * m);
    let mut pick = |rng: &mut Rng| -> u32 {
        let r = rng.f64() * total;
        cum.partition_point(|&c| c < r).min(n - 1) as u32
    };
    for _ in 0..m {
        let u = pick(&mut rng);
        let v = pick(&mut rng);
        if u != v {
            edges.push((u, v));
            edges.push((v, u));
        }
    }
    CsrGraph::from_edges(n, &edges).expect("generated edges in range")
}

/// R-MAT recursive quadrant sampling (Graph500 style).  `scale` gives
/// n = 2^scale nodes; `edge_factor` edges per node; (a, b, c) the quadrant
/// probabilities (d = 1-a-b-c).  Defaults (0.57, 0.19, 0.19) give the
/// classic skewed community structure.
pub fn rmat(
    scale: u32,
    edge_factor: usize,
    a: f64,
    b: f64,
    c: f64,
    seed: u64,
) -> CsrGraph {
    let n = 1usize << scale;
    let m = n * edge_factor;
    let mut rng = Rng::new(seed);
    let mut edges = Vec::with_capacity(m);
    for _ in 0..m {
        let (mut u, mut v) = (0usize, 0usize);
        for _ in 0..scale {
            let r = rng.f64();
            let (du, dv) = if r < a {
                (0, 0)
            } else if r < a + b {
                (0, 1)
            } else if r < a + b + c {
                (1, 0)
            } else {
                (1, 1)
            };
            u = (u << 1) | du;
            v = (v << 1) | dv;
        }
        edges.push((u as u32, v as u32));
    }
    CsrGraph::from_edges(n, &edges).expect("generated edges in range")
}

/// 2-D grid with 4-neighbour connectivity (rows*cols nodes).
pub fn grid2d(rows: usize, cols: usize) -> CsrGraph {
    let n = rows * cols;
    let id = |r: usize, c: usize| (r * cols + c) as u32;
    let mut edges = Vec::with_capacity(4 * n);
    for r in 0..rows {
        for c in 0..cols {
            if r + 1 < rows {
                edges.push((id(r, c), id(r + 1, c)));
                edges.push((id(r + 1, c), id(r, c)));
            }
            if c + 1 < cols {
                edges.push((id(r, c), id(r, c + 1)));
                edges.push((id(r, c + 1), id(r, c)));
            }
        }
    }
    CsrGraph::from_edges(n, &edges).expect("generated edges in range")
}

/// Complete graph on n nodes (every ordered pair, self loops included) —
/// the fully-dense extreme the dense-fallback backend targets.
pub fn clique(n: usize) -> CsrGraph {
    let mut edges = Vec::with_capacity(n * n);
    for u in 0..n as u32 {
        for v in 0..n as u32 {
            edges.push((u, v));
        }
    }
    CsrGraph::from_edges(n, &edges).expect("generated edges in range")
}

/// Star graph: node 0 connected to all others (extreme imbalance case).
pub fn star(n: usize) -> CsrGraph {
    let mut edges = Vec::with_capacity(2 * (n - 1));
    for v in 1..n as u32 {
        edges.push((0, v));
        edges.push((v, 0));
    }
    CsrGraph::from_edges(n, &edges).expect("generated edges in range")
}

/// Ring graph (every node degree 2) — perfectly uniform workload.
pub fn ring(n: usize) -> CsrGraph {
    let mut edges = Vec::with_capacity(2 * n);
    for u in 0..n as u32 {
        let v = (u + 1) % n as u32;
        edges.push((u, v));
        edges.push((v, u));
    }
    CsrGraph::from_edges(n, &edges).expect("generated edges in range")
}

/// Stochastic block model: `blocks` communities of `block_size` nodes;
/// within-community edge prob `p_in`, across `p_out`.
pub fn sbm(
    blocks: usize,
    block_size: usize,
    p_in: f64,
    p_out: f64,
    seed: u64,
) -> CsrGraph {
    let n = blocks * block_size;
    let mut rng = Rng::new(seed);
    let mut edges = Vec::new();
    // Expected-degree sampling to avoid O(n^2) for large sparse cases.
    for u in 0..n {
        let bu = u / block_size;
        let deg_in = (p_in * block_size as f64).round() as usize;
        let deg_out = (p_out * (n - block_size) as f64).round() as usize;
        for _ in 0..deg_in {
            let v = bu * block_size + rng.below(block_size);
            edges.push((u as u32, v as u32));
        }
        for _ in 0..deg_out {
            let mut v = rng.below(n);
            if v / block_size == bu {
                v = (v + block_size) % n;
            }
            edges.push((u as u32, v as u32));
        }
    }
    CsrGraph::from_edges(n, &edges).expect("generated edges in range")
}

#[cfg(test)]
mod tests {
    use crate::util::stats;

    use super::*;

    #[test]
    fn er_degree_close_to_target() {
        let g = erdos_renyi(2000, 8.0, 1);
        let avg = g.avg_degree();
        assert!((7.0..9.0).contains(&avg), "avg degree {avg}");
    }

    #[test]
    fn er_deterministic() {
        assert_eq!(erdos_renyi(500, 4.0, 7), erdos_renyi(500, 4.0, 7));
        assert_ne!(erdos_renyi(500, 4.0, 7), erdos_renyi(500, 4.0, 8));
    }

    #[test]
    fn ba_power_law_tail() {
        let g = barabasi_albert(3000, 3, 2);
        let degs: Vec<f64> = g.degrees().iter().map(|&d| d as f64).collect();
        // Power-law: CV well above an ER graph of the same average degree.
        let cv_ba = stats::cv(&degs);
        let er = erdos_renyi(3000, g.avg_degree(), 2);
        let cv_er =
            stats::cv(&er.degrees().iter().map(|&d| d as f64).collect::<Vec<_>>());
        assert!(
            cv_ba > 2.0 * cv_er,
            "BA CV {cv_ba:.2} should dwarf ER CV {cv_er:.2}"
        );
        assert!(g.is_symmetric());
    }

    #[test]
    fn power_law_skew_tracks_the_exponent() {
        let heavy = power_law(3000, 8.0, 2.3, 7);
        let light = power_law(3000, 8.0, 3.5, 7);
        assert!(heavy.is_symmetric());
        let cv = |g: &CsrGraph| {
            stats::cv(&g.degrees().iter().map(|&d| d as f64).collect::<Vec<_>>())
        };
        // Lower exponent -> heavier tail -> higher degree CV; both beat ER.
        let er = erdos_renyi(3000, heavy.avg_degree(), 7);
        assert!(
            cv(&heavy) > 1.5 * cv(&light),
            "alpha=2.3 CV {:.2} must dwarf alpha=3.5 CV {:.2}",
            cv(&heavy),
            cv(&light)
        );
        assert!(cv(&light) > 1.5 * cv(&er), "{} vs {}", cv(&light), cv(&er));
        // Hubs live at low node ids (the contiguous-partition adversary).
        let head: usize = (0..30).map(|i| heavy.degree(i)).sum();
        let tail: usize = (2970..3000).map(|i| heavy.degree(i)).sum();
        assert!(head > 10 * tail.max(1), "head {head} vs tail {tail}");
        // Deterministic in the seed.
        assert_eq!(power_law(500, 6.0, 2.5, 1), power_law(500, 6.0, 2.5, 1));
        assert_ne!(power_law(500, 6.0, 2.5, 1), power_law(500, 6.0, 2.5, 2));
    }

    #[test]
    fn rmat_skewed() {
        let g = rmat(12, 8, 0.57, 0.19, 0.19, 3);
        assert_eq!(g.n, 4096);
        let max_d = g.max_degree() as f64;
        assert!(
            max_d > 8.0 * g.avg_degree(),
            "rmat should have heavy hubs (max {max_d}, avg {})",
            g.avg_degree()
        );
    }

    #[test]
    fn grid_structure() {
        let g = grid2d(5, 7);
        assert_eq!(g.n, 35);
        // Interior nodes degree 4, corners 2.
        assert_eq!(g.degree(0), 2);
        assert_eq!(g.degree(3 * 7 + 3), 4);
        assert!(g.is_symmetric());
    }

    #[test]
    fn star_and_ring() {
        let s = star(100);
        assert_eq!(s.degree(0), 99);
        assert_eq!(s.degree(1), 1);
        let r = ring(64);
        assert!(r.degrees().iter().all(|&d| d == 2));
    }

    #[test]
    fn clique_is_complete() {
        let g = clique(12);
        assert_eq!(g.nnz(), 144);
        assert!(g.degrees().iter().all(|&d| d == 12));
        assert!(g.is_symmetric());
    }

    #[test]
    fn sbm_clusters() {
        let g = sbm(4, 64, 0.2, 0.001, 5);
        assert_eq!(g.n, 256);
        // Most edges within the block.
        let mut within = 0usize;
        for u in 0..g.n {
            for &v in g.row(u) {
                if u / 64 == v as usize / 64 {
                    within += 1;
                }
            }
        }
        assert!(within as f64 > 0.7 * g.nnz() as f64);
    }
}
