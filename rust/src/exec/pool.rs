//! A reusable scoped-thread worker pool — the offline substitute for rayon.
//!
//! One [`WorkerPool`] value is threaded through every subsystem that fans
//! work out (BSB construction, per-slot gathers, the host kernel emulation,
//! coordinator preprocessing), so the whole process follows one parallelism
//! configuration instead of each call site choosing its own width.  The
//! width caps each parallel *region*, not the process: concurrent regions
//! (e.g. several preprocessing workers building BSBs at once) can briefly
//! oversubscribe — acceptable for scoped CPU-bound bursts, and bounded by
//! `preprocess_workers × threads`.  Workers are `std::thread::scope`
//! threads: they may borrow the
//! caller's stack (mutable disjoint slices, shared graph/problem refs) with
//! no `'static` bound and no unsafe, and they are guaranteed joined when the
//! call returns — every `WorkerPool` method is a synchronous parallel
//! region, which is exactly the shape the engine's determinism argument
//! needs (see EXPERIMENTS.md §Perf).

/// Shared fan-out configuration.  `threads == 1` degrades every method to a
/// plain in-place loop (no threads are spawned), which is the deterministic
/// reference the tests pin the parallel paths against.
#[derive(Clone, Copy, Debug)]
pub struct WorkerPool {
    threads: usize,
}

impl WorkerPool {
    /// A pool fanning out to `threads` workers (clamped to at least 1).
    pub fn new(threads: usize) -> WorkerPool {
        WorkerPool { threads: threads.max(1) }
    }

    /// A pool sized to the machine's available parallelism.
    pub fn auto() -> WorkerPool {
        let threads = std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1);
        WorkerPool::new(threads)
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    pub fn is_serial(&self) -> bool {
        self.threads <= 1
    }

    /// Consume `items`, applying `f` to each one, sharded contiguously
    /// across workers.  Item order *within* a shard is preserved; shards run
    /// concurrently, so `f`'s side effects must be disjoint per item (the
    /// callers hand each item its own `&mut` slice).  Worker panics
    /// propagate to the caller when the scope joins.
    pub fn run_items<T, F>(&self, items: Vec<T>, f: F)
    where
        T: Send,
        F: Fn(T) + Sync,
    {
        let shards = self.shard(items);
        if shards.len() <= 1 {
            for shard in shards {
                for item in shard {
                    f(item);
                }
            }
            return;
        }
        std::thread::scope(|s| {
            for shard in shards {
                let f = &f;
                s.spawn(move || {
                    for item in shard {
                        f(item);
                    }
                });
            }
        });
    }

    /// Split `0..n` into at most `threads` balanced contiguous ranges, apply
    /// `f` to each concurrently, and return the results **in range order**
    /// (shard 0's result first).  This is the primitive the parallel BSB
    /// build stitches shards with: contiguity + ordered results make the
    /// assembled output identical to a serial run.
    pub fn map_ranges<R, F>(&self, n: usize, f: F) -> Vec<R>
    where
        R: Send,
        F: Fn(std::ops::Range<usize>) -> R + Sync,
    {
        let ranges = split_ranges(n, self.threads);
        if ranges.len() <= 1 {
            return ranges.into_iter().map(f).collect();
        }
        std::thread::scope(|s| {
            let handles: Vec<_> = ranges
                .into_iter()
                .map(|r| {
                    let f = &f;
                    s.spawn(move || f(r))
                })
                .collect();
            handles
                .into_iter()
                // Re-raise a worker panic with its original payload so an
                // upstream `catch_unwind` (the coordinator's panic
                // isolation) sees the real message, not a generic join
                // error.
                .map(|h| {
                    h.join().unwrap_or_else(|p| std::panic::resume_unwind(p))
                })
                .collect()
        })
    }

    /// Contiguous, order-preserving split of `items` into at most `threads`
    /// near-equal shards.
    fn shard<T>(&self, mut items: Vec<T>) -> Vec<Vec<T>> {
        let parts = self.threads.min(items.len());
        if parts <= 1 {
            return if items.is_empty() { Vec::new() } else { vec![items] };
        }
        let total = items.len();
        let base = total / parts;
        let extra = total % parts;
        let mut shards = Vec::with_capacity(parts);
        for i in 0..parts {
            let take = base + usize::from(i < extra);
            let rest = items.split_off(take);
            shards.push(items);
            items = rest;
        }
        debug_assert!(items.is_empty());
        shards
    }
}

/// Balanced contiguous split of `0..n` into at most `parts` ranges (always
/// at least one range, possibly empty when `n == 0`).
fn split_ranges(n: usize, parts: usize) -> Vec<std::ops::Range<usize>> {
    let parts = parts.clamp(1, n.max(1));
    let base = n / parts;
    let extra = n % parts;
    let mut out = Vec::with_capacity(parts);
    let mut lo = 0;
    for i in 0..parts {
        let hi = lo + base + usize::from(i < extra);
        out.push(lo..hi);
        lo = hi;
    }
    debug_assert_eq!(lo, n);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn run_items_visits_everything_once() {
        for threads in [1, 2, 4, 7] {
            let pool = WorkerPool::new(threads);
            let n = 103;
            let mut hits = vec![0u8; n];
            {
                let items: Vec<(usize, &mut u8)> =
                    hits.iter_mut().enumerate().collect();
                pool.run_items(items, |(i, h)| {
                    *h += 1;
                    assert!(i < n);
                });
            }
            assert!(hits.iter().all(|&h| h == 1), "threads={threads}");
        }
    }

    #[test]
    fn run_items_empty_is_noop() {
        let pool = WorkerPool::new(4);
        let items: Vec<usize> = Vec::new();
        pool.run_items(items, |_| panic!("must not be called"));
    }

    #[test]
    fn map_ranges_ordered_and_exhaustive() {
        for threads in [1, 3, 8] {
            let pool = WorkerPool::new(threads);
            let got = pool.map_ranges(100, |r| r);
            assert!(got.len() <= threads);
            let mut lo = 0;
            for r in &got {
                assert_eq!(r.start, lo);
                lo = r.end;
            }
            assert_eq!(lo, 100);
        }
    }

    #[test]
    fn map_ranges_more_threads_than_items() {
        let pool = WorkerPool::new(16);
        let sums = pool.map_ranges(3, |r| r.sum::<usize>());
        assert_eq!(sums.iter().sum::<usize>(), 3);
        assert_eq!(sums.len(), 3);
    }

    #[test]
    fn map_ranges_zero_items() {
        let pool = WorkerPool::new(4);
        let got = pool.map_ranges(0, |r| r.len());
        assert_eq!(got, vec![0]);
    }

    #[test]
    fn shard_balance() {
        let pool = WorkerPool::new(4);
        let shards = pool.shard((0..10).collect::<Vec<_>>());
        let sizes: Vec<usize> = shards.iter().map(|s| s.len()).collect();
        assert_eq!(sizes, vec![3, 3, 2, 2]);
        let flat: Vec<usize> = shards.into_iter().flatten().collect();
        assert_eq!(flat, (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn run_items_sums_match_serial() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        for threads in [1, 2, 5] {
            let pool = WorkerPool::new(threads);
            let acc = AtomicUsize::new(0);
            pool.run_items((0..100).collect(), |i: usize| {
                acc.fetch_add(i, Ordering::Relaxed);
            });
            assert_eq!(acc.into_inner(), 4950, "threads={threads}");
        }
    }
}
