//! Host (CPU) emulation of the fused 3S kernel call — the offline
//! [`CallExecutor`].
//!
//! It consumes exactly what the PJRT kernel consumes — the *gathered*
//! [`CallBuffers`] (Q blocks, K̂/V̂ row stacks, TCB bitmaps), not the graph —
//! so running the full driver path through it exercises the BSB build, the
//! bucket plan, the gathers, the pipeline and the scatters end to end with
//! no artifacts present.  The benches use it as the dispatch stage of the
//! host-pipeline sweep; the tests pin it against the dense host reference.
//!
//! Determinism contract: per-slot computation is pure and written to
//! disjoint output slices in a fixed iteration order, so outputs are
//! bit-identical for every `WorkerPool` width.

use std::collections::BTreeMap;
use std::path::PathBuf;

use anyhow::Result;

use crate::kernels::backward::BackwardExecutor;
use crate::kernels::gather::CallBuffers;
use crate::kernels::AttentionProblem;
use crate::runtime::Manifest;
use crate::{BITMAP_WORDS, TCB_C, TCB_R};

use super::engine::CallExecutor;
use super::pool::WorkerPool;

/// Offline stand-in for the PJRT-backed kernel dispatch.  Slot-parallel
/// over the supplied pool (slots are independent row windows).
pub struct HostExecutor<'p> {
    pool: &'p WorkerPool,
}

impl<'p> HostExecutor<'p> {
    pub fn new(pool: &'p WorkerPool) -> HostExecutor<'p> {
        HostExecutor { pool }
    }
}

impl CallExecutor for HostExecutor<'_> {
    fn bucket(
        &mut self,
        t_bucket: usize,
        bufs: &CallBuffers,
        x: &AttentionProblem,
        batch: usize,
    ) -> Result<Vec<f32>> {
        let mut o = vec![0.0f32; batch * TCB_R * x.dv];
        let slots: Vec<(usize, &mut [f32])> =
            o.chunks_mut(TCB_R * x.dv).enumerate().collect();
        self.pool.run_items(slots, |(slot, o_slot)| {
            slot_attention(slot, t_bucket, bufs, x, o_slot, None);
        });
        Ok(o)
    }

    fn partial(
        &mut self,
        chunk_t: usize,
        bufs: &CallBuffers,
        x: &AttentionProblem,
        batch: usize,
    ) -> Result<(Vec<f32>, Vec<f32>, Vec<f32>)> {
        let mut o = vec![0.0f32; batch * TCB_R * x.dv];
        let mut m = vec![f32::NEG_INFINITY; batch * TCB_R];
        let mut l = vec![0.0f32; batch * TCB_R];
        {
            let slots: Vec<(usize, ((&mut [f32], &mut [f32]), &mut [f32]))> = o
                .chunks_mut(TCB_R * x.dv)
                .zip(m.chunks_mut(TCB_R))
                .zip(l.chunks_mut(TCB_R))
                .enumerate()
                .collect();
            self.pool.run_items(slots, |(slot, ((o_slot, m_slot), l_slot))| {
                slot_attention(
                    slot,
                    chunk_t,
                    bufs,
                    x,
                    o_slot,
                    Some((m_slot, l_slot)),
                );
            });
        }
        Ok((o, m, l))
    }

    fn lanes(
        &mut self,
        rows: usize,
        t_lanes: usize,
        bufs: &CallBuffers,
        x: &AttentionProblem,
        batch: usize,
    ) -> Result<Vec<f32>> {
        let mut o = vec![0.0f32; batch * rows * x.dv];
        let slots: Vec<(usize, &mut [f32])> =
            o.chunks_mut(rows * x.dv).enumerate().collect();
        self.pool.run_items(slots, |(slot, o_slot)| {
            lane_attention(slot, rows, t_lanes, bufs, x, o_slot);
        });
        Ok(o)
    }
}

impl BackwardExecutor for HostExecutor<'_> {
    fn backward(
        &mut self,
        t_bucket: usize,
        bufs: &CallBuffers,
        d_out: &[f32],
        x: &AttentionProblem,
        batch: usize,
    ) -> Result<(Vec<f32>, Vec<f32>, Vec<f32>)> {
        let d = x.d;
        let lanes = t_bucket * TCB_C;
        let mut gq = vec![0.0f32; batch * TCB_R * d];
        let mut gk = vec![0.0f32; batch * lanes * d];
        let mut gv = vec![0.0f32; batch * lanes * d];
        {
            let slots: Vec<(usize, ((&mut [f32], &mut [f32]), &mut [f32]))> = gq
                .chunks_mut(TCB_R * d)
                .zip(gk.chunks_mut(lanes * d))
                .zip(gv.chunks_mut(lanes * d))
                .enumerate()
                .collect();
            self.pool.run_items(slots, |(slot, ((gq_s, gk_s), gv_s))| {
                slot_backward(slot, t_bucket, bufs, d_out, x, gq_s, gk_s, gv_s);
            });
        }
        Ok((gq, gk, gv))
    }
}

/// One slot's backward pass over its gathered lanes, matching the
/// `fused3s_bwd` kernel's semantics: E recomputed from the staged
/// (pre-scaled) Q̂ and K̂, then per row
/// `dP_j = dO·V_j`, `row = Σ_j E_j dP_j`, `dS_j = E_j (dP_j − row)`,
/// `dQ̂ += Σ_j dS_j K_j`, `dK̂_j += dS_j Q̂`, `dV̂_j += E_j dO`.
/// f32 accumulation throughout (what the artifact does on device).
#[allow(clippy::too_many_arguments)]
fn slot_backward(
    slot: usize,
    t: usize,
    bufs: &CallBuffers,
    d_out: &[f32],
    x: &AttentionProblem,
    gq_slot: &mut [f32],
    gk_slot: &mut [f32],
    gv_slot: &mut [f32],
) {
    let d = x.d;
    let lanes = t * TCB_C;
    let q_base = slot * TCB_R * d;
    let kv_base = slot * lanes;
    let bm_base = slot * t * BITMAP_WORDS;
    let mut scores: Vec<(usize, f32)> = Vec::with_capacity(lanes);
    for r in 0..TCB_R {
        scores.clear();
        let q_row = &bufs.q[q_base + r * d..q_base + (r + 1) * d];
        let do_row = &d_out[q_base + r * d..q_base + (r + 1) * d];
        let mut m_row = f32::NEG_INFINITY;
        for j in 0..t {
            let bm = &bufs.bm[bm_base + j * BITMAP_WORDS..][..BITMAP_WORDS];
            for c in 0..TCB_C {
                let bit = r * TCB_C + c;
                if (bm[bit / 32] >> (bit % 32)) & 1 == 0 {
                    continue;
                }
                let lane = j * TCB_C + c;
                let k_row = &bufs.k[(kv_base + lane) * d..][..d];
                let mut s = 0.0f32;
                for cc in 0..d {
                    s += q_row[cc] * k_row[cc];
                }
                m_row = m_row.max(s);
                scores.push((lane, s));
            }
        }
        if scores.is_empty() {
            continue; // fully masked row: all gradients stay zero
        }
        let mut l_row = 0.0f32;
        for (_, s) in scores.iter_mut() {
            *s = (*s - m_row).exp();
            l_row += *s;
        }
        // dP per lane, plus the softmax-Jacobian row term Σ E_j dP_j.
        let mut row_sum = 0.0f32;
        let mut dps: Vec<f32> = Vec::with_capacity(scores.len());
        for &(lane, p) in &scores {
            let e = p / l_row;
            let v_row = &bufs.v[(kv_base + lane) * d..][..d];
            let mut dp = 0.0f32;
            for cc in 0..d {
                dp += do_row[cc] * v_row[cc];
            }
            dps.push(dp);
            row_sum += e * dp;
        }
        let gq_row = &mut gq_slot[r * d..(r + 1) * d];
        for (&(lane, p), &dp) in scores.iter().zip(&dps) {
            let e = p / l_row;
            let ds = e * (dp - row_sum);
            let k_row = &bufs.k[(kv_base + lane) * d..][..d];
            let gk_row = &mut gk_slot[lane * d..(lane + 1) * d];
            let gv_row = &mut gv_slot[lane * d..(lane + 1) * d];
            for cc in 0..d {
                gq_row[cc] += ds * k_row[cc];
                gk_row[cc] += ds * q_row[cc];
                gv_row[cc] += e * do_row[cc];
            }
        }
    }
}

/// One slot's masked attention over its gathered lanes, matching the Pallas
/// kernel's semantics: scores only where the bitmap bit is set, stable
/// softmax per row, normalised output; fully-masked rows produce zeros
/// (and `(m, l) = (-inf, 0)` in partial mode, the empty merge identity).
fn slot_attention(
    slot: usize,
    t: usize,
    bufs: &CallBuffers,
    x: &AttentionProblem,
    o_slot: &mut [f32],
    ml: Option<(&mut [f32], &mut [f32])>,
) {
    let (d, dv) = (x.d, x.dv);
    let lanes = t * TCB_C;
    let q_base = slot * TCB_R * d;
    let kv_base = slot * lanes;
    let bm_base = slot * t * BITMAP_WORDS;
    let mut scores: Vec<(usize, f32)> = Vec::with_capacity(lanes);
    let mut ml = ml;
    for r in 0..TCB_R {
        scores.clear();
        let q_row = &bufs.q[q_base + r * d..q_base + (r + 1) * d];
        let mut m_row = f32::NEG_INFINITY;
        for j in 0..t {
            let bm = &bufs.bm[bm_base + j * BITMAP_WORDS..][..BITMAP_WORDS];
            for c in 0..TCB_C {
                let bit = r * TCB_C + c;
                if (bm[bit / 32] >> (bit % 32)) & 1 == 0 {
                    continue;
                }
                let lane = j * TCB_C + c;
                let k_row = &bufs.k[(kv_base + lane) * d..][..d];
                let mut s = 0.0f32;
                for cc in 0..d {
                    s += q_row[cc] * k_row[cc];
                }
                m_row = m_row.max(s);
                scores.push((lane, s));
            }
        }
        if let Some((m_slot, l_slot)) = ml.as_mut() {
            m_slot[r] = m_row;
            l_slot[r] = 0.0;
        }
        if scores.is_empty() {
            continue; // fully masked row: o stays zero
        }
        let mut l_row = 0.0f32;
        for (_, s) in scores.iter_mut() {
            *s = (*s - m_row).exp();
            l_row += *s;
        }
        let o_row = &mut o_slot[r * dv..(r + 1) * dv];
        for &(lane, p) in &scores {
            let w = p / l_row;
            let v_row = &bufs.v[(kv_base + lane) * dv..][..dv];
            for cc in 0..dv {
                o_row[cc] += w * v_row[cc];
            }
        }
        if let Some((_, l_slot)) = ml.as_mut() {
            l_slot[r] = l_row;
        }
    }
}

/// One slot's masked attention over its gathered *lanes* (the narrow/dense
/// geometry; see `crate::bsb::geometry`).  Per row, the op sequence is
/// **identical** to [`slot_attention`]'s for that row — the lanes hold the
/// row's nonzero columns in the same ascending original-column order the
/// wide TCB walk visits, scores fold into the max in that order, and the
/// exp/sum and weighted-V accumulation run in that same order — so a row
/// computed on the lane path is bit-identical to the wide path (pinned by
/// `rust/tests/packing_equivalence.rs`).
fn lane_attention(
    slot: usize,
    rows: usize,
    t_lanes: usize,
    bufs: &CallBuffers,
    x: &AttentionProblem,
    o_slot: &mut [f32],
) {
    let (d, dv) = (x.d, x.dv);
    let q_base = slot * rows * d;
    let kv_base = slot * t_lanes;
    let bm_base = slot * t_lanes;
    let mut scores: Vec<(usize, f32)> = Vec::with_capacity(t_lanes);
    for r in 0..rows {
        scores.clear();
        let q_row = &bufs.q[q_base + r * d..q_base + (r + 1) * d];
        let mut m_row = f32::NEG_INFINITY;
        for li in 0..t_lanes {
            if (bufs.bm[bm_base + li] >> r) & 1 == 0 {
                continue;
            }
            let k_row = &bufs.k[(kv_base + li) * d..][..d];
            let mut s = 0.0f32;
            for cc in 0..d {
                s += q_row[cc] * k_row[cc];
            }
            m_row = m_row.max(s);
            scores.push((li, s));
        }
        if scores.is_empty() {
            continue; // fully masked row (or zero-mask padding lane): o stays zero
        }
        let mut l_row = 0.0f32;
        for (_, s) in scores.iter_mut() {
            *s = (*s - m_row).exp();
            l_row += *s;
        }
        let o_row = &mut o_slot[r * dv..(r + 1) * dv];
        for &(li, p) in &scores {
            let w = p / l_row;
            let v_row = &bufs.v[(kv_base + li) * dv..][..dv];
            for cc in 0..dv {
                o_row[cc] += w * v_row[cc];
            }
        }
    }
}

/// A manifest carrying only the bucketing configuration — enough to build
/// drivers and plans with **no artifacts on disk**, for the offline host
/// path (benches, tests, cold CI).
pub fn offline_manifest(
    rw_batch: usize,
    t_buckets: &[usize],
    chunk_t: usize,
) -> Manifest {
    Manifest {
        dir: PathBuf::from("."),
        rw_batch,
        t_buckets: t_buckets.to_vec(),
        d_kernel: vec![32, 64, 128],
        d_model: vec![64, 128, 256],
        m_tile: 1024,
        chunk_t,
        d_head: 64,
        entries: BTreeMap::new(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bsb;
    use crate::graph::generators;
    use crate::kernels::gather;
    use crate::kernels::reference;
    use crate::util::prng::Rng;

    #[test]
    fn host_kernel_matches_dense_reference_on_one_call() {
        let g = generators::erdos_renyi(64, 5.0, 3).with_self_loops();
        let bsb = bsb::build(&g);
        let d = 16;
        let mut rng = Rng::new(11);
        let (q, k, v) = (
            rng.normal_vec(64 * d, 1.0),
            rng.normal_vec(64 * d, 1.0),
            rng.normal_vec(64 * d, 1.0),
        );
        let x = AttentionProblem::new(64, d, &q, &k, &v, 0.5);
        let t_cap = (0..bsb.num_rw).map(|i| bsb.rw_tcbs(i)).max().unwrap();
        let rws: Vec<u32> = (0..bsb.num_rw as u32).collect();
        let mut bufs = CallBuffers::default();
        let pool = WorkerPool::new(1);
        gather::gather_call_with(&pool, &mut bufs, &rws, t_cap, &bsb, &x, rws.len());
        let mut exec = HostExecutor::new(&pool);
        let o = exec.bucket(t_cap, &bufs, &x, rws.len()).unwrap();
        let mut out = vec![0.0f32; 64 * d];
        gather::scatter_call(&mut out, &o, &rws, 64, d);
        let want = reference::dense_attention_host(&g, &x);
        let err = reference::max_abs_diff(&out, &want);
        assert!(err < 1e-4, "max err {err}");
    }

    #[test]
    fn partial_mode_reports_merge_state() {
        // One row attending within a single TCB: l must equal the softmax
        // denominator and o the normalised output.
        let g = crate::graph::CsrGraph::from_edges(16, &[(0, 0), (0, 1)]).unwrap();
        let bsb = bsb::build(&g);
        let d = 4;
        let mut rng = Rng::new(5);
        let (q, k, v) = (
            rng.normal_vec(16 * d, 1.0),
            rng.normal_vec(16 * d, 1.0),
            rng.normal_vec(16 * d, 1.0),
        );
        let x = AttentionProblem::new(16, d, &q, &k, &v, 1.0);
        let pool = WorkerPool::new(1);
        let mut bufs = CallBuffers::default();
        gather::gather_call_with(&pool, &mut bufs, &[0], 1, &bsb, &x, 1);
        let mut exec = HostExecutor::new(&pool);
        let (o, m, l) = exec.partial(1, &bufs, &x, 1).unwrap();
        // Row 0 has two logits; rows 1.. are fully masked.
        assert!(l[0] > 0.0 && m[0].is_finite());
        assert_eq!(l[1], 0.0);
        assert_eq!(m[1], f32::NEG_INFINITY);
        assert!(o[d..TCB_R * d].iter().all(|&z| z == 0.0));
        // Merging the single chunk into an empty state reproduces o.
        let mut st = crate::kernels::fused::MergeState::new(d);
        st.merge(&o[..TCB_R * d], &m[..TCB_R], &l[..TCB_R]);
        for c in 0..d {
            assert!((st.o[c] - o[c]).abs() < 1e-6);
        }
    }

    #[test]
    fn slot_parallelism_is_bit_exact() {
        let g = generators::barabasi_albert(300, 5, 7).with_self_loops();
        let bsb = bsb::build(&g);
        let d = 8;
        let mut rng = Rng::new(9);
        let (q, k, v) = (
            rng.normal_vec(300 * d, 1.0),
            rng.normal_vec(300 * d, 1.0),
            rng.normal_vec(300 * d, 1.0),
        );
        let x = AttentionProblem::new(300, d, &q, &k, &v, 1.0);
        let t_cap = (0..bsb.num_rw).map(|i| bsb.rw_tcbs(i)).max().unwrap();
        let rws: Vec<u32> = (0..bsb.num_rw as u32).collect();
        let serial = WorkerPool::new(1);
        let wide = WorkerPool::new(4);
        let mut b1 = CallBuffers::default();
        let mut b2 = CallBuffers::default();
        gather::gather_call_with(&serial, &mut b1, &rws, t_cap, &bsb, &x, rws.len());
        gather::gather_call_with(&wide, &mut b2, &rws, t_cap, &bsb, &x, rws.len());
        assert_eq!(b1.q, b2.q);
        assert_eq!(b1.k, b2.k);
        assert_eq!(b1.v, b2.v);
        assert_eq!(b1.bm, b2.bm);
        let o1 = HostExecutor::new(&serial)
            .bucket(t_cap, &b1, &x, rws.len())
            .unwrap();
        let o2 = HostExecutor::new(&wide)
            .bucket(t_cap, &b2, &x, rws.len())
            .unwrap();
        assert_eq!(o1, o2);
    }
}
