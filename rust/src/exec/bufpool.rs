//! A recycling arena for [`CallBuffers`] — the per-call Q/K̂/V̂/bitmap
//! staging allocations.
//!
//! Buffer-reuse invariant (EXPERIMENTS.md §Perf): a `CallBuffers` handed
//! back by [`BufferPool::release`] keeps its heap capacity, and
//! `CallBuffers::reset` only zeroes the bitmap words — stale f32 payload is
//! masked by zero bitmap bits, so recycling buffers across calls *and across
//! coordinator requests* is numerically exact while skipping the dominant
//! per-call memset.  The pool is `Sync`; the engine and the coordinator
//! share one instance so steady-state serving performs no staging
//! allocations at all.

use std::sync::Mutex;

use crate::kernels::gather::CallBuffers;
use crate::util::sync::lock_unpoisoned;

/// Thread-safe free list of recycled call buffers.
#[derive(Default)]
pub struct BufferPool {
    free: Mutex<Vec<CallBuffers>>,
}

impl BufferPool {
    pub fn new() -> BufferPool {
        BufferPool::default()
    }

    /// Take a recycled buffer, or a fresh empty one if the pool is dry.
    /// Callers must `reset` it for their call shape before gathering.
    ///
    /// The free list is a plain `Vec` whose push/pop leave it valid at
    /// every point, so a worker that panicked while holding the lock (a
    /// caught gather/scatter panic) must not wedge the arena: the lock is
    /// recovered, at worst losing the buffer the panicking thread held.
    pub fn acquire(&self) -> CallBuffers {
        lock_unpoisoned(&self.free).pop().unwrap_or_default()
    }

    /// Return a buffer to the pool for reuse.
    pub fn release(&self, bufs: CallBuffers) {
        lock_unpoisoned(&self.free).push(bufs);
    }

    /// Number of buffers currently pooled (tests/metrics).
    pub fn available(&self) -> usize {
        lock_unpoisoned(&self.free).len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn acquire_release_recycles_capacity() {
        let pool = BufferPool::new();
        assert_eq!(pool.available(), 0);
        let mut b = pool.acquire();
        b.reset(4, 8, 16, 16);
        let cap = b.q.capacity();
        assert!(cap >= 4 * 16 * 16);
        pool.release(b);
        assert_eq!(pool.available(), 1);
        let b2 = pool.acquire();
        assert_eq!(b2.q.capacity(), cap);
        assert_eq!(pool.available(), 0);
    }

    #[test]
    fn dry_pool_hands_out_fresh_buffers() {
        let pool = BufferPool::new();
        let b = pool.acquire();
        assert!(b.q.is_empty() && b.bm.is_empty());
    }
}
