//! The parallel, pipelined host execution engine (EXPERIMENTS.md §Perf).
//!
//! The paper wins by minimising *device* data movement; on the host side of
//! this reproduction the analogous cost is the memory engine — BSB build,
//! per-call Q/K̂/V̂ gathers, dispatch, scatter — which the seed ran fully
//! serially on one thread.  This module makes that path parallel and
//! latency-hiding while keeping the serial policy as the bit-exact
//! reference:
//!
//! * [`pool::WorkerPool`] — a reusable scoped-thread worker pool (rayon is
//!   unavailable offline) shared by every fan-out site in the process;
//! * [`bufpool::BufferPool`] — a recycling arena for `CallBuffers`, reused
//!   across calls *and* across coordinator requests;
//! * [`engine::Engine`] / [`engine::ExecPolicy`] — the double-buffered
//!   gather → dispatch → scatter pipeline the drivers run through;
//! * [`engine::CallExecutor`] — the dispatch seam (PJRT online,
//!   [`host_kernel::HostExecutor`] offline);
//! * [`host_kernel`] — CPU emulation of the fused 3S call, so benches and
//!   tests drive the full host path with no artifacts.

pub mod bufpool;
pub mod engine;
pub mod host_kernel;
pub mod pool;

pub use bufpool::BufferPool;
pub use engine::{CallExecutor, Engine, ExecPolicy};
pub use host_kernel::{offline_manifest, HostExecutor};
pub use pool::WorkerPool;
