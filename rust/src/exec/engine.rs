//! The pipelined host execution engine.
//!
//! The fused/unfused drivers decompose each kernel call into three host
//! stages:
//!
//! 1. **gather** — fill a [`CallBuffers`] with the call's Q block, K̂/V̂ row
//!    stacks and TCB bitmaps (CPU + memory bound, embarrassingly parallel
//!    per batch slot);
//! 2. **dispatch** — hand the staged buffers to the executor (PJRT upload +
//!    kernel execution, or the offline host emulation).  PJRT clients are
//!    not `Send`, so dispatch always runs on the calling thread;
//! 3. **scatter** — commit the call's output blocks into the result matrix
//!    (or fold partial-softmax chunks into merge state).
//!
//! [`Engine::run_pipeline`] overlaps the three stages with a double-buffered
//! software pipeline: while call *i* dispatches on the calling thread, a
//! scoped gather worker stages call *i+1* into a second buffer, and a
//! scoped scatter worker commits call *i−1*.  Buffers circulate through a
//! free-list channel (capacity = `pipeline_depth`) backed by the shared
//! [`BufferPool`], so steady state performs zero staging allocations.
//!
//! Determinism: gather order, dispatch order, and scatter order are all the
//! schedule order — the pipeline only changes *when* stages run, never what
//! they compute or in which sequence outputs are committed.  Together with
//! the slot-sharded gathers writing disjoint slices, every `ExecPolicy`
//! produces **bit-identical** output (pinned by `rust/tests/exec_parallel.rs`).

use anyhow::{anyhow, Result};

use crate::bsb::bucket::Call;
use crate::bsb::geometry::{LaneCall, LaneSet};
use crate::bsb::Bsb;
use crate::fault::{self, FaultSite};
use crate::kernels::gather::{self, CallBuffers};
use crate::kernels::{AttentionBatch, AttentionProblem};
use crate::trace::{self, TraceSite};

use super::bufpool::BufferPool;
use super::pool::WorkerPool;

/// Host-execution knobs (the ablation axes of the host-pipeline bench).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ExecPolicy {
    /// Fan-out width for parallel stages (BSB build shards, gather slots,
    /// host-kernel slots).  1 = fully serial reference.
    pub threads: usize,
    /// Call buffers in flight.  1 = stages run back-to-back per call;
    /// 2 = classic double buffering (gather of call *i+1* overlaps dispatch
    /// of call *i*).  Values above the call count are clamped.
    pub pipeline_depth: usize,
}

impl ExecPolicy {
    /// The deterministic serial reference policy.
    pub fn serial() -> ExecPolicy {
        ExecPolicy { threads: 1, pipeline_depth: 1 }
    }

    /// Machine-sized policy: all available cores, double buffering.
    pub fn auto() -> ExecPolicy {
        ExecPolicy { threads: WorkerPool::auto().threads(), pipeline_depth: 2 }
    }

    pub fn is_serial(&self) -> bool {
        self.threads <= 1 && self.pipeline_depth <= 1
    }
}

impl Default for ExecPolicy {
    fn default() -> Self {
        ExecPolicy::auto()
    }
}

/// Shared host-execution context: policy + worker pool + buffer arena.
/// One `Engine` serves a whole process (the coordinator shares its engine
/// between preprocessing workers and the executor thread).
pub struct Engine {
    pub policy: ExecPolicy,
    pub pool: WorkerPool,
    pub buffers: BufferPool,
}

impl Engine {
    pub fn new(policy: ExecPolicy) -> Engine {
        Engine {
            policy,
            pool: WorkerPool::new(policy.threads),
            buffers: BufferPool::new(),
        }
    }

    /// The serial reference engine (the bit-exactness oracle policy).
    pub fn serial() -> Engine {
        Engine::new(ExecPolicy::serial())
    }

    /// Machine-sized engine.
    pub fn auto() -> Engine {
        Engine::new(ExecPolicy::auto())
    }

    /// Run `n` calls through the gather → dispatch → scatter pipeline.
    ///
    /// * `gather` fills the call's buffers; it runs on a scoped worker and
    ///   may itself fan out over `self.pool`.
    /// * `dispatch` turns staged buffers into output tensors (flat f32
    ///   vectors); it always runs on the calling thread, in call order.
    /// * `scatter` commits outputs; it runs on a scoped worker, strictly in
    ///   call order (required by the chunked-softmax merge).
    ///
    /// On dispatch error the pipeline drains and the error is returned;
    /// scatter is never invoked for the failed or subsequent calls.
    pub fn run_pipeline<G, D, S>(
        &self,
        n: usize,
        gather: G,
        mut dispatch: D,
        mut scatter: S,
    ) -> Result<()>
    where
        G: Fn(usize, &mut CallBuffers) + Sync,
        D: FnMut(usize, &CallBuffers) -> Result<Vec<Vec<f32>>>,
        S: FnMut(usize, Vec<Vec<f32>>) + Send,
    {
        if n == 0 {
            return Ok(());
        }
        // Scoped workers don't inherit the caller's thread-local ambient
        // span, so capture it once here and target it explicitly from every
        // stage (gather/scatter run on their own threads when pipelined).
        let ambient = trace::current_span();
        if self.policy.is_serial() {
            let mut bufs = self.buffers.acquire();
            let result = (|| -> Result<()> {
                for i in 0..n {
                    fault::fire_unit(FaultSite::Gather);
                    let g = trace::span(TraceSite::Gather, ambient, i as u64);
                    gather(i, &mut bufs);
                    drop(g);
                    fault::fire(FaultSite::Dispatch)
                        .map_err(anyhow::Error::from)?;
                    let d = trace::span(TraceSite::Dispatch, ambient, i as u64);
                    let outs = dispatch(i, &bufs)?;
                    drop(d);
                    fault::fire_unit(FaultSite::Scatter);
                    let s = trace::span(TraceSite::Scatter, ambient, i as u64);
                    scatter(i, outs);
                    drop(s);
                }
                Ok(())
            })();
            // Recycle the staging buffer on success *and* error.
            self.buffers.release(bufs);
            return result;
        }

        let depth = self.policy.pipeline_depth.clamp(1, n);
        std::thread::scope(|s| -> Result<()> {
            // Staged buffers travel gather → dispatch on `full`, and are
            // recycled dispatch → gather on `free` (primed to `depth`).
            let (full_tx, full_rx) = std::sync::mpsc::channel::<(usize, CallBuffers)>();
            let (free_tx, free_rx) = std::sync::mpsc::channel::<CallBuffers>();
            for _ in 0..depth {
                // invariant: free_rx is alive — it is moved into the
                // gatherer spawned below, in this same scope.
                free_tx.send(self.buffers.acquire()).expect("receiver alive");
            }

            let gather = &gather;
            let gatherer = s.spawn(move || {
                for i in 0..n {
                    let Ok(mut bufs) = free_rx.recv() else { break };
                    fault::fire_unit(FaultSite::Gather);
                    // Instants, not spans: gather overlaps dispatch in
                    // wall-time, and overlapping B/E pairs on one tid
                    // would mis-nest in the Chrome viewer.
                    trace::instant(TraceSite::Gather, ambient, i as u64, 0);
                    gather(i, &mut bufs);
                    if full_tx.send((i, bufs)).is_err() {
                        break;
                    }
                }
                drop(full_tx);
                // Collect leftover buffers once the driver drops `free_tx`.
                free_rx.into_iter().collect::<Vec<_>>()
            });

            let (done_tx, done_rx) = std::sync::mpsc::channel::<(usize, Vec<Vec<f32>>)>();
            let scatterer = s.spawn(move || {
                while let Ok((i, outs)) = done_rx.recv() {
                    fault::fire_unit(FaultSite::Scatter);
                    trace::instant(TraceSite::Scatter, ambient, i as u64, 0);
                    scatter(i, outs);
                }
            });

            let mut failure: Option<anyhow::Error> = None;
            for _ in 0..n {
                let Ok((i, bufs)) = full_rx.recv() else {
                    failure = Some(anyhow!("gather stage exited early"));
                    break;
                };
                if let Err(e) = fault::fire(FaultSite::Dispatch) {
                    self.buffers.release(bufs);
                    failure = Some(anyhow::Error::from(e));
                    break;
                }
                let d = trace::span(TraceSite::Dispatch, ambient, i as u64);
                let dispatched = dispatch(i, &bufs);
                drop(d);
                match dispatched {
                    Ok(outs) => {
                        let _ = free_tx.send(bufs);
                        if done_tx.send((i, outs)).is_err() {
                            failure = Some(anyhow!("scatter stage exited early"));
                            break;
                        }
                    }
                    Err(e) => {
                        self.buffers.release(bufs);
                        failure = Some(e);
                        break;
                    }
                }
            }
            drop(free_tx);
            drop(full_rx);
            drop(done_tx);
            match gatherer.join() {
                Ok(leftover) => {
                    for bufs in leftover {
                        self.buffers.release(bufs);
                    }
                }
                Err(p) => std::panic::resume_unwind(p),
            }
            if let Err(p) = scatterer.join() {
                std::panic::resume_unwind(p);
            }
            match failure {
                Some(e) => Err(e),
                None => Ok(()),
            }
        })
    }

    /// Pipeline a plan's regular bucketed calls over **every head** of a
    /// batch: slot-parallel gathers, caller-supplied dispatch, scatter into
    /// the head-major `out` (`heads × n × dv`).  Shared by the fused and
    /// unfused drivers.
    ///
    /// Work items are ordered call-major with heads inner (call 0 head 0,
    /// call 0 head 1, …), so the pipeline overlaps head *h+1*'s gather with
    /// head *h*'s dispatch — no idle gap at head boundaries — and each
    /// call's head-invariant TCB bitmaps are staged **once per batch** up
    /// front and memcpy'd into every head's buffers instead of re-walked
    /// from the BSB per head.
    ///
    /// Determinism: for each head, the (gather, dispatch, scatter) sequence
    /// is exactly the single-head schedule, and heads write disjoint output
    /// blocks — so the multi-head result bit-matches a per-head loop under
    /// every `ExecPolicy` (pinned by `rust/tests/multihead_equivalence.rs`).
    ///
    /// `dispatch` receives `(call, head, staged buffers)`.
    pub fn run_bucketed<F>(
        &self,
        calls: &[Call],
        bsb: &Bsb,
        x: &AttentionBatch,
        batch: usize,
        out: &mut [f32],
        mut dispatch: F,
    ) -> Result<()>
    where
        F: FnMut(&Call, usize, &CallBuffers) -> Result<Vec<f32>>,
    {
        let heads = x.heads;
        let (n_rows, dv) = (x.n, x.dv);
        let per_head = n_rows * dv;
        debug_assert_eq!(out.len(), heads * per_head);
        // Head-invariant structural gather, once per call per batch.  Only
        // worth materialising when there is a second head to amortize it
        // over: at heads == 1 the inline bitmap walk inside the pipelined
        // gather stage is strictly cheaper than an up-front staging pass
        // (and holds no per-call buffers alive), so that path is kept.
        let bitmaps: Vec<Vec<i32>> = if heads > 1 {
            calls
                .iter()
                .map(|c| gather::stage_call_bitmaps(bsb, &c.rws, c.t_bucket, batch))
                .collect()
        } else {
            Vec::new()
        };
        self.run_pipeline(
            calls.len() * heads,
            |i, bufs| {
                let (ci, h) = (i / heads, i % heads);
                let call = &calls[ci];
                let xh = x.head(h);
                if heads > 1 {
                    gather::gather_call_staged(
                        &self.pool,
                        bufs,
                        &call.rws,
                        call.t_bucket,
                        &bitmaps[ci],
                        bsb,
                        &xh,
                        batch,
                    );
                } else {
                    gather::gather_call_with(
                        &self.pool,
                        bufs,
                        &call.rws,
                        call.t_bucket,
                        bsb,
                        &xh,
                        batch,
                    );
                }
            },
            |i, bufs| {
                let (ci, h) = (i / heads, i % heads);
                dispatch(&calls[ci], h, bufs).map(|o| vec![o])
            },
            |i, outs| {
                let (ci, h) = (i / heads, i % heads);
                let out_h = &mut out[h * per_head..(h + 1) * per_head];
                gather::scatter_call(out_h, &outs[0], &calls[ci].rws, n_rows, dv);
            },
        )
    }

    /// Pipeline a hybrid plan's *lane* calls (narrow 8-row or dense 16-row
    /// geometry; see [`crate::bsb::geometry`]) over every head of a batch —
    /// the lane-geometry analogue of [`Engine::run_bucketed`], with the
    /// same item order (calls major, heads inner), the same once-per-batch
    /// staging of head-invariant structure (lane masks instead of TCB
    /// bitmaps), and the same determinism argument: per head the schedule
    /// equals the single-head sequence and lane windows scatter to rows
    /// disjoint from every other call's, so any `ExecPolicy` bit-matches
    /// the serial reference.
    ///
    /// `dispatch` receives `(call, head, staged buffers)`.
    pub fn run_lane_calls<F>(
        &self,
        set: &LaneSet,
        calls: &[LaneCall],
        x: &AttentionBatch,
        batch: usize,
        out: &mut [f32],
        mut dispatch: F,
    ) -> Result<()>
    where
        F: FnMut(&LaneCall, usize, &CallBuffers) -> Result<Vec<f32>>,
    {
        let heads = x.heads;
        let (n_rows, dv) = (x.n, x.dv);
        let per_head = n_rows * dv;
        debug_assert_eq!(out.len(), heads * per_head);
        // Head-invariant lane masks, staged once per call per batch when a
        // second head exists to amortize them over (same trade-off as the
        // bucketed path's bitmap staging).
        let masks: Vec<Vec<i32>> = if heads > 1 {
            calls
                .iter()
                .map(|c| gather::stage_lane_masks(set, &c.windows, c.t_lanes, batch))
                .collect()
        } else {
            Vec::new()
        };
        self.run_pipeline(
            calls.len() * heads,
            |i, bufs| {
                let (ci, h) = (i / heads, i % heads);
                let call = &calls[ci];
                let xh = x.head(h);
                if heads > 1 {
                    gather::gather_lane_call_staged(
                        &self.pool,
                        bufs,
                        set,
                        &call.windows,
                        call.t_lanes,
                        &masks[ci],
                        &xh,
                        batch,
                    );
                } else {
                    gather::gather_lane_call_with(
                        &self.pool,
                        bufs,
                        set,
                        &call.windows,
                        call.t_lanes,
                        &xh,
                        batch,
                    );
                }
            },
            |i, bufs| {
                let (ci, h) = (i / heads, i % heads);
                dispatch(&calls[ci], h, bufs).map(|o| vec![o])
            },
            |i, outs| {
                let (ci, h) = (i / heads, i % heads);
                let out_h = &mut out[h * per_head..(h + 1) * per_head];
                gather::scatter_lane_call(
                    out_h,
                    &outs[0],
                    set.rows,
                    &calls[ci].windows,
                    n_rows,
                    dv,
                );
            },
        )
    }
}

/// Executes one staged kernel call — the seam between the host pipeline and
/// whatever actually computes: the PJRT runtime online, or the
/// [`host_kernel`](super::host_kernel) emulation offline (benches and the
/// bit-exactness tests run the full driver path through it with no
/// artifacts present).
pub trait CallExecutor {
    /// Regular bucketed call at TCB capacity `t_bucket`: return the output
    /// blocks, `batch * 16 * dv` row-major.
    fn bucket(
        &mut self,
        t_bucket: usize,
        bufs: &CallBuffers,
        x: &AttentionProblem,
        batch: usize,
    ) -> Result<Vec<f32>>;

    /// Partial (chunked row-window) call at chunk capacity `chunk_t`:
    /// return `(o, m, l)` — normalised chunk outputs (`batch * 16 * dv`)
    /// plus the per-row softmax max/denominator (`batch * 16` each).
    fn partial(
        &mut self,
        chunk_t: usize,
        bufs: &CallBuffers,
        x: &AttentionProblem,
        batch: usize,
    ) -> Result<(Vec<f32>, Vec<f32>, Vec<f32>)>;

    /// Lane-geometry call (narrow 8-row or dense 16-row windows; see
    /// [`crate::bsb::geometry`]): `batch` windows of `rows` rows ×
    /// `t_lanes` column lanes staged via `CallBuffers::reset_lanes`;
    /// return the output blocks, `batch * rows * dv` row-major.
    ///
    /// Default: unsupported.  Only executors with lane kernels override
    /// this (the offline host emulation today — no PJRT lane artifacts
    /// exist yet, so the hybrid backend is host-only).
    fn lanes(
        &mut self,
        _rows: usize,
        _t_lanes: usize,
        _bufs: &CallBuffers,
        _x: &AttentionProblem,
        _batch: usize,
    ) -> Result<Vec<f32>> {
        Err(anyhow!("lane-geometry calls unsupported by this executor"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn policy(threads: usize, depth: usize) -> ExecPolicy {
        ExecPolicy { threads, pipeline_depth: depth }
    }

    /// A toy 3-stage workload: gather writes i into the buffer, dispatch
    /// doubles it, scatter records it.  Checks ordering and completeness
    /// across policies.
    fn run_toy(engine: &Engine, n: usize) -> Vec<f32> {
        let mut seen = Vec::new();
        engine
            .run_pipeline(
                n,
                |i, bufs| {
                    bufs.q.clear();
                    bufs.q.push(i as f32);
                },
                |_, bufs| Ok(vec![vec![bufs.q[0] * 2.0]]),
                |i, outs| {
                    assert_eq!(outs[0][0], (i * 2) as f32);
                    seen.push(outs[0][0]);
                },
            )
            .unwrap();
        seen
    }

    #[test]
    fn pipeline_commits_in_order_across_policies() {
        let want: Vec<f32> = (0..17).map(|i| (i * 2) as f32).collect();
        for (t, d) in [(1, 1), (1, 2), (4, 1), (4, 2), (4, 4)] {
            let engine = Engine::new(policy(t, d));
            assert_eq!(run_toy(&engine, 17), want, "threads={t} depth={d}");
        }
    }

    #[test]
    fn pipeline_zero_calls() {
        let engine = Engine::auto();
        assert!(run_toy(&engine, 0).is_empty());
    }

    #[test]
    fn buffers_are_recycled_into_the_arena() {
        let engine = Engine::new(policy(2, 2));
        run_toy(&engine, 8);
        assert_eq!(engine.buffers.available(), 2);
        let serial = Engine::serial();
        run_toy(&serial, 3);
        assert_eq!(serial.buffers.available(), 1);
    }

    #[test]
    fn dispatch_error_propagates_and_stops_scatter() {
        let engine = Engine::new(policy(2, 2));
        let mut committed = Vec::new();
        let err = engine
            .run_pipeline(
                10,
                |i, bufs| {
                    bufs.q.clear();
                    bufs.q.push(i as f32);
                },
                |i, _| {
                    if i == 3 {
                        anyhow::bail!("boom at {i}");
                    }
                    Ok(vec![vec![i as f32]])
                },
                |i, _| committed.push(i),
            )
            .unwrap_err();
        assert!(format!("{err}").contains("boom"));
        assert_eq!(committed, vec![0, 1, 2]);
    }
}
