//! `cargo bench planner` — the adaptive-planner sweep (EXPERIMENTS.md
//! §Planner): `Backend::Auto` vs every fixed backend across four synthetic
//! workload families (er / sbm / star / molecule-batch), through the
//! offline host pipeline (no artifacts).
//!
//! The bench is the measuring half of the planner story: it times each
//! *fixed* feasible backend, feeds those measurements into the planner's
//! cost model (exactly the coordinator's online refinement loop), then
//! lets the tuned planner resolve the workload and times the auto choice.
//! Every auto run is checked **bit-identical** to the same workload forced
//! to the resolved backend before its row prints.
//!
//! Prints one JSON row per (generator × backend), plus a summary row per
//! generator.  Gates (asserted):
//!
//! * auto is never slower than the **worst** feasible fixed backend;
//! * on the two synthetic extremes (`er`, the regular low-CV case, and
//!   `star`, the mega-hub case) auto matches the **best** measured fixed
//!   backend.
//!
//! The dense fallback has no offline host emulation, so it is not part of
//! the fixed series here (the planner's dense decision is pinned by
//! `rust/tests/planner_selection.rs` instead).  Env knobs:
//! `F3S_BENCH_FULL=1` for full sizes/iterations.
//!
//! Besides the per-row JSON stream, the bench snapshots
//! `BENCH_planner.json` at the repo root: per generator, every backend's
//! time **normalized by the serial-policy fused reference run** on the
//! same workload (ROADMAP item 4 — ratios survive container changes where
//! wall-clock baselines don't).

use std::fmt::Write as _;
use std::path::Path;

use fused3s::exec::{offline_manifest, Engine, ExecPolicy};
use fused3s::graph::batch::{batched_dataset, BatchKind};
use fused3s::graph::{generators, CsrGraph};
use fused3s::kernels::{AttentionBatch, Backend, ExecCtx, Plan};
use fused3s::planner::{CostModel, GraphProfile, Planner, DEFAULT_BUCKETS};
use fused3s::util::prng::Rng;
use fused3s::util::timing::{bench, BenchConfig};

/// The fixed comparison series (host-executable backends).  Hybrid is in
/// the offline candidate set, so it must be measured here too — otherwise
/// an auto resolution to it could not be checked against a forced run.
const FIXED: &[Backend] = &[
    Backend::Fused3S,
    Backend::Hybrid,
    Backend::UnfusedStable,
    Backend::CpuCsr,
];

/// The two workloads the acceptance gate calls "synthetic extremes".
const EXTREMES: &[&str] = &["er", "star"];

fn workloads(full: bool) -> Vec<(&'static str, CsrGraph)> {
    let n = if full { 8192 } else { 2048 };
    vec![
        ("er", generators::erdos_renyi(n, 8.0, 41).with_self_loops()),
        (
            "sbm",
            generators::sbm(n / 128, 128, 0.05, 0.0005, 42).with_self_loops(),
        ),
        ("star", generators::star(n).with_self_loops()),
        (
            "molecule",
            batched_dataset(n / 16, 12, 28, 43, BatchKind::Molecule)
                .0
                .with_self_loops(),
        ),
    ]
}

fn main() {
    let full = std::env::var("F3S_BENCH_FULL").is_ok();
    let cfg = if full { BenchConfig::default() } else { BenchConfig::quick() };
    let d = 32usize;
    let man = offline_manifest(8, DEFAULT_BUCKETS, 128);
    let engine = Engine::new(ExecPolicy { threads: 4, pipeline_depth: 2 });
    // The planner under test: offline candidates (no dense), factory
    // constants, refined below from this bench's own measurements.
    let planner = Planner::offline(CostModel::default());

    println!("planner: auto vs fixed backends, tuned-from-measurement (full={full})");
    let mut snapshot_rows: Vec<(String, String, Vec<(String, f64)>)> = Vec::new();
    for (gen, g) in workloads(full) {
        let n = g.n;
        let profile = GraphProfile::from_csr(&g);
        let mut rng = Rng::new(0x9A71);
        let q = rng.normal_vec(n * d, 1.0);
        let k = rng.normal_vec(n * d, 1.0);
        let v = rng.normal_vec(n * d, 1.0);
        let scale = 1.0 / (d as f32).sqrt();
        let x = AttentionBatch::new(n, d, d, 1, &q, &k, &v, scale);

        // The normalization anchor: the fused backend on the *serial*
        // reference policy.  Every snapshot entry is ms / ref_ms, so the
        // baseline survives machine and container changes.
        let serial = Engine::serial();
        let ref_plan = Plan::new(&man, &g, Backend::Fused3S, &serial)
            .expect("serial fused reference");
        let ref_ms = bench("serial_ref", &cfg, || {
            let o = ref_plan
                .execute(&mut ExecCtx::host(&serial), &x)
                .expect("serial reference executes");
            assert_eq!(o.len(), n * d);
        })
        .median_ms();

        // 1. Measure every fixed backend; feed measurements to the model.
        let mut measured: Vec<(Backend, Option<f64>, Vec<f32>)> = Vec::new();
        for &b in FIXED {
            match Plan::new(&man, &g, b, &engine) {
                Err(e) => {
                    println!(
                        "{{\"bench\":\"planner\",\"generator\":\"{gen}\",\
                         \"backend\":\"{}\",\"feasible\":false,\
                         \"error\":\"{e}\"}}",
                        b.name()
                    );
                    measured.push((b, None, Vec::new()));
                }
                Ok(plan) => {
                    let out = plan
                        .execute(&mut ExecCtx::host(&engine), &x)
                        .expect("fixed backend executes");
                    let r = bench(b.name(), &cfg, || {
                        let o = plan
                            .execute(&mut ExecCtx::host(&engine), &x)
                            .expect("fixed backend executes");
                        assert_eq!(o.len(), n * d);
                    });
                    let ms = r.median_ms();
                    let cells = fused3s::planner::cells(b, &profile)
                        .expect("feasible backend has cells");
                    planner.observe(b, cells, ms / 1e3);
                    let predicted_ms = planner
                        .snapshot()
                        .predict_s(b, &profile)
                        .map(|sec| sec * 1e3)
                        .unwrap_or(0.0);
                    println!(
                        "{{\"bench\":\"planner\",\"generator\":\"{gen}\",\
                         \"backend\":\"{}\",\"feasible\":true,\"n\":{n},\
                         \"ms\":{ms:.3},\"cells\":{cells:.0},\
                         \"predicted_ms\":{predicted_ms:.3}}}",
                        b.name()
                    );
                    measured.push((b, Some(ms), out));
                }
            }
        }

        // 2. The tuned planner resolves the workload; run the auto choice.
        let decision = planner.resolve(&g);
        let auto_plan =
            Plan::new(&man, &g, decision.backend, &engine).expect("auto plan");
        let auto_out = auto_plan
            .execute(&mut ExecCtx::host(&engine), &x)
            .expect("auto executes");
        // Bit-exactness gate: auto must equal the forced-backend run.
        let forced = measured
            .iter()
            .find(|(b, _, _)| *b == decision.backend)
            .expect("auto resolved to a fixed-series backend");
        assert_eq!(
            auto_out, forced.2,
            "{gen}: auto output diverged from forced {}",
            decision.backend.name()
        );
        let r = bench("auto", &cfg, || {
            let o = auto_plan
                .execute(&mut ExecCtx::host(&engine), &x)
                .expect("auto executes");
            assert_eq!(o.len(), n * d);
        });
        let auto_ms = r.median_ms();

        let mut ratios: Vec<(String, f64)> = measured
            .iter()
            .filter_map(|(b, ms, _)| {
                ms.map(|m| (b.name().to_string(), m / ref_ms))
            })
            .collect();
        ratios.push(("auto".to_string(), auto_ms / ref_ms));
        snapshot_rows.push((
            gen.to_string(),
            decision.backend.name().to_string(),
            ratios,
        ));

        // 3. Gates + summary row.
        let feasible: Vec<(Backend, f64)> = measured
            .iter()
            .filter_map(|(b, ms, _)| ms.map(|m| (*b, m)))
            .collect();
        let worst = feasible
            .iter()
            .cloned()
            .fold((Backend::Fused3S, 0.0f64), |a, b| if b.1 > a.1 { b } else { a });
        let best = feasible
            .iter()
            .cloned()
            .fold((Backend::Fused3S, f64::INFINITY), |a, b| {
                if b.1 < a.1 {
                    b
                } else {
                    a
                }
            });
        let never_slower_than_worst = auto_ms <= worst.1 * 1.10;
        let matches_best = decision.backend == best.0;
        println!(
            "{{\"bench\":\"planner\",\"generator\":\"{gen}\",\
             \"backend\":\"auto\",\"resolved\":\"{}\",\"chunked\":{},\
             \"ms\":{auto_ms:.3},\"predicted_ms\":{:.3},\
             \"best_fixed\":\"{}\",\"best_fixed_ms\":{:.3},\
             \"worst_fixed\":\"{}\",\"worst_fixed_ms\":{:.3},\
             \"never_slower_than_worst\":{never_slower_than_worst},\
             \"matches_best\":{matches_best}}}",
            decision.backend.name(),
            decision.chunked,
            decision.predicted_s * 1e3,
            best.0.name(),
            best.1,
            worst.0.name(),
            worst.1,
        );
        assert!(
            never_slower_than_worst,
            "{gen}: auto {auto_ms:.3} ms slower than worst fixed {:.3} ms",
            worst.1
        );
        if EXTREMES.contains(&gen) {
            assert!(
                matches_best,
                "{gen}: auto resolved {} but best fixed was {}",
                decision.backend.name(),
                best.0.name()
            );
        }
    }

    // Snapshot the normalized baseline at the repo root.
    let mut body = String::new();
    for (i, (gen, resolved, ratios)) in snapshot_rows.iter().enumerate() {
        if i > 0 {
            body.push(',');
        }
        let mut entries = String::new();
        for (j, (name, ratio)) in ratios.iter().enumerate() {
            if j > 0 {
                entries.push(',');
            }
            write!(entries, "\n   \"{name}\": {ratio:.4}").unwrap();
        }
        write!(
            body,
            "\n  \"{gen}\": {{\n   \"resolved\": \"{resolved}\",{entries}\n  }}"
        )
        .unwrap();
    }
    let payload = format!(
        "{{\n \"bench\": \"planner\",\n \"generators\": {{{body}\n }},\n \
         \"unit\": \"time ratio vs the serial-policy fused reference run on \
         the same workload (machine-scaled, not wall-clock)\"\n}}\n",
    );
    let root = Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .expect("crate lives under the repo root");
    let path = root.join("BENCH_planner.json");
    std::fs::write(&path, payload).expect("write BENCH_planner.json");
    println!("wrote {}", path.display());
}
