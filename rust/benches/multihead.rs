//! `cargo bench multihead` — the head-batching sweep (EXPERIMENTS.md
//! §Multi-head): one multi-head `AttentionBatch` call vs the old per-head
//! loop, over `heads ∈ {1, 2, 4, 8}` × `d ∈ {32, 64}` on GT-calibrated
//! dataset generators, through the offline host pipeline (no artifacts).
//!
//! The batched call amortizes the per-call TCB-bitmap staging across heads
//! and pipelines head *h+1*'s gather over head *h*'s dispatch, so it should
//! win at heads ≥ 4 — every row is checked **bit-identical** to the
//! per-head loop before it prints.
//!
//! Prints one JSON row per (dataset, d, heads) config.  Env knobs:
//! `F3S_BENCH_FULL=1` for full iteration counts.

use fused3s::exec::{offline_manifest, Engine, ExecPolicy};
use fused3s::graph::datasets;
use fused3s::kernels::{AttentionBatch, Backend, ExecCtx, Plan};
use fused3s::runtime::Manifest;
use fused3s::util::prng::Rng;
use fused3s::util::timing::{bench, BenchConfig};

const BUCKETS: &[usize] = &[4, 8, 16, 32, 64, 128];

fn manifest() -> Manifest {
    offline_manifest(32, BUCKETS, 128)
}

fn main() {
    let full = std::env::var("F3S_BENCH_FULL").is_ok();
    let cfg = if full { BenchConfig::default() } else { BenchConfig::quick() };
    let names: &[&str] =
        if full { &["cora-sim", "pubmed-sim", "github-sim"] } else { &["cora-sim"] };
    let man = manifest();
    let engine = Engine::new(ExecPolicy { threads: 4, pipeline_depth: 2 });

    println!("multihead: batched AttentionBatch call vs per-head loop (full={full})");
    for name in names {
        let ds = datasets::by_name(name).expect("dataset");
        let g = &ds.graph;
        let plan = Plan::new(&man, g, Backend::Fused3S, &engine).expect("plan");
        for &d in &[32usize, 64] {
            for &heads in &[1usize, 2, 4, 8] {
                let mut rng = Rng::new(0x4EAD + heads as u64);
                let n = g.n;
                let q = rng.normal_vec(heads * n * d, 1.0);
                let k = rng.normal_vec(heads * n * d, 1.0);
                let v = rng.normal_vec(heads * n * d, 1.0);
                let scale = 1.0 / (d as f32).sqrt();
                let x = AttentionBatch::new(n, d, d, heads, &q, &k, &v, scale);

                // Correctness gate: batched must bit-match the loop.
                let batched = plan
                    .execute(&mut ExecCtx::host(&engine), &x)
                    .expect("batched");
                let mut looped = Vec::with_capacity(x.out_len());
                for h in 0..heads {
                    let xh = x.head(h);
                    looped.extend_from_slice(
                        &plan
                            .execute(
                                &mut ExecCtx::host(&engine),
                                &AttentionBatch::single(&xh),
                            )
                            .expect("per-head"),
                    );
                }
                let bit_identical = batched == looped;
                assert!(bit_identical, "{name} d={d} heads={heads} diverged");

                let r_loop = bench("per_head_loop", &cfg, || {
                    for h in 0..heads {
                        let xh = x.head(h);
                        let o = plan
                            .execute(
                                &mut ExecCtx::host(&engine),
                                &AttentionBatch::single(&xh),
                            )
                            .expect("per-head");
                        assert_eq!(o.len(), n * d);
                    }
                });
                let r_batch = bench("batched", &cfg, || {
                    let o = plan
                        .execute(&mut ExecCtx::host(&engine), &x)
                        .expect("batched");
                    assert_eq!(o.len(), heads * n * d);
                });
                let (loop_ms, batch_ms) = (r_loop.median_ms(), r_batch.median_ms());
                let speedup = if batch_ms > 0.0 { loop_ms / batch_ms } else { 0.0 };
                println!(
                    "{{\"bench\":\"multihead\",\"dataset\":\"{name}\",\"n\":{n},\
                     \"d\":{d},\"heads\":{heads},\"per_head_loop_ms\":{loop_ms:.3},\
                     \"batched_ms\":{batch_ms:.3},\"speedup\":{speedup:.3},\
                     \"bit_identical\":{bit_identical}}}"
                );
            }
        }
    }
}
