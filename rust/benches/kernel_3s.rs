//! `cargo bench kernel_3s` — Figure 5: 3S kernel comparison on the
//! single-graph suite (set F3S_BENCH_FULL=1 for the full suite + full
//! iteration counts; default is a representative subset sized for CI).

use fused3s::experiments::{fig5, report};
use fused3s::graph::datasets;
use fused3s::kernels::Backend;
use fused3s::runtime::Runtime;
use fused3s::util::timing::BenchConfig;

fn main() {
    let full = std::env::var("F3S_BENCH_FULL").is_ok();
    let rt = match Runtime::from_default_artifacts() {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("kernel_3s bench requires artifacts (`make artifacts`): {e:#}");
            return;
        }
    };
    let suite: Vec<_> = if full {
        datasets::suite_single()
    } else {
        datasets::suite_single()
            .into_iter()
            .filter(|d| {
                [
                    "citeseer-sim",
                    "cora-sim",
                    "pubmed-sim",
                    "github-sim",
                    "blog-sim",
                    "yelp-sim",
                ]
                .contains(&d.name)
            })
            .collect()
    };
    let cfg = if full { BenchConfig::default() } else { BenchConfig::quick() };
    let j = fig5::run(&rt, &suite, &Backend::kernel_series(), 64, &cfg, "fig5")
        .expect("fig5 bench");
    let p = report::write_json("bench_kernel_3s", &j).expect("write json");
    println!("wrote {}", p.display());
}
