//! `cargo bench host_pipeline` — the host execution engine sweep
//! (EXPERIMENTS.md §Perf): end-to-end host path (parallel BSB build +
//! bucket plan + slot-parallel gathers + pipelined dispatch/scatter through
//! the offline host kernel) over `threads ∈ {1,2,4,8}` ×
//! `pipeline_depth ∈ {1,2}` on the `erdos_renyi(65536, 8.0)` workload.
//!
//! Prints one JSON row per config (machine-readable for the BENCH_*
//! trajectory) plus a human-readable table.  Every config's output is
//! checked bit-identical against the serial policy before its row prints.
//!
//! Env knobs: `F3S_BENCH_FULL=1` for full iteration counts,
//! `F3S_HOST_BENCH_N=<n>` to shrink the graph for smoke runs.

use fused3s::exec::{offline_manifest, Engine, ExecPolicy, HostExecutor};
use fused3s::graph::generators;
use fused3s::kernels::fused::{FusedDriver, FusedOpts};
use fused3s::kernels::{AttentionBatch, AttentionProblem};
use fused3s::util::prng::Rng;
use fused3s::util::timing::{bench, BenchConfig};

const BUCKETS: &[usize] = &[4, 8, 16, 32, 64, 128];

fn main() {
    let full = std::env::var("F3S_BENCH_FULL").is_ok();
    let n: usize = std::env::var("F3S_HOST_BENCH_N")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(65536);
    let deg = 8.0;
    let d = 64;
    let cfg = if full { BenchConfig::default() } else { BenchConfig::quick() };

    println!("host_pipeline: erdos_renyi({n}, {deg}) d={d} (full={full})");
    let g = generators::erdos_renyi(n, deg, 1).with_self_loops();
    let mut rng = Rng::new(2);
    let q = rng.normal_vec(n * d, 1.0);
    let k = rng.normal_vec(n * d, 1.0);
    let v = rng.normal_vec(n * d, 1.0);
    let x = AttentionProblem::new(n, d, &q, &k, &v, 0.125);
    let batch = AttentionBatch::single(&x);
    let man = offline_manifest(32, BUCKETS, 128);
    let opts = FusedOpts::default();

    // Serial reference: the baseline row and the bit-exactness oracle.
    let serial = Engine::serial();
    let serial_driver =
        FusedDriver::new(&man, &g, opts).expect("serial driver");
    let want = serial_driver
        .execute_with(&batch, &serial, &mut HostExecutor::new(&serial.pool))
        .expect("serial run");

    let mut serial_e2e = 0.0f64;
    for threads in [1usize, 2, 4, 8] {
        for depth in [1usize, 2] {
            let policy = ExecPolicy { threads, pipeline_depth: depth };
            let engine = Engine::new(policy);
            let driver = FusedDriver::new_with(&man, &g, opts, &engine)
                .expect("driver");
            assert_eq!(driver.bsb, serial_driver.bsb, "BSB build must match");
            let got = driver
                .execute_with(&batch, &engine, &mut HostExecutor::new(&engine.pool))
                .expect("run");
            let bit_identical = got == want;
            assert!(bit_identical, "threads={threads} depth={depth} diverged");

            let build = bench(
                &format!("build t{threads}"),
                &cfg,
                || {
                    let b = FusedDriver::new_with(&man, &g, opts, &engine)
                        .expect("driver");
                    assert!(b.plan.stats.real_tcbs > 0);
                },
            );
            let run = bench(&format!("run t{threads} p{depth}"), &cfg, || {
                let out = driver
                    .execute_with(&batch, &engine, &mut HostExecutor::new(&engine.pool))
                    .expect("run");
                assert_eq!(out.len(), n * d);
            });
            let e2e_ms = build.median_ms() + run.median_ms();
            if threads == 1 && depth == 1 {
                serial_e2e = e2e_ms;
            }
            let speedup = if e2e_ms > 0.0 { serial_e2e / e2e_ms } else { 0.0 };
            println!(
                "{{\"bench\":\"host_pipeline\",\"n\":{n},\"deg\":{deg},\"d\":{d},\
                 \"threads\":{threads},\"pipeline_depth\":{depth},\
                 \"build_ms\":{:.3},\"run_ms\":{:.3},\"e2e_ms\":{:.3},\
                 \"speedup_e2e\":{:.3},\"bit_identical\":{bit_identical}}}",
                build.median_ms(),
                run.median_ms(),
                e2e_ms,
                speedup,
            );
            println!("  {}", build.row());
            println!("  {}", run.row());
        }
    }
}
