//! `cargo bench gt_e2e` — Figure 8: end-to-end Graph Transformer inference
//! with the 3S kernel swapped between backends, d ∈ {64, 128, 256}.
//! F3S_BENCH_FULL=1 runs the paper's 10 blocks; default 3 blocks for CI.

use fused3s::experiments::{fig8, report};
use fused3s::graph::datasets;
use fused3s::runtime::Runtime;
use fused3s::util::timing::BenchConfig;

fn main() {
    let full = std::env::var("F3S_BENCH_FULL").is_ok();
    let rt = match Runtime::from_default_artifacts() {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("bench requires artifacts (`make artifacts`): {e:#}");
            return;
        }
    };
    let names: &[&str] = if full {
        &["cora-sim", "citeseer-sim", "pubmed-sim", "github-sim", "molhiv-sim"]
    } else {
        &["cora-sim", "molhiv-sim"]
    };
    let suite: Vec<_> = names
        .iter()
        .map(|n| datasets::by_name(n).expect("dataset"))
        .collect();
    let dims: Vec<usize> = if full { vec![64, 128, 256] } else { vec![64, 128] };
    let blocks = if full { 10 } else { 3 };
    let cfg = if full { BenchConfig::default() } else { BenchConfig::quick() };
    let j = fig8::run(&rt, &suite, &dims, &fig8::series(), blocks, &cfg)
        .expect("fig8 bench");
    let p = report::write_json("bench_gt_e2e", &j).expect("write json");
    println!("wrote {}", p.display());
}
