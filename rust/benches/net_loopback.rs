//! `cargo bench net_loopback` — wire-serving round-trip cost over a real
//! loopback TCP connection (DESIGN.md §13), offline under host emulation
//! so it runs without artifacts.
//!
//! Two series:
//!   * `inline`      — every submit carries the full CSR (handshake off:
//!                     a fresh connection per batch, so nothing is known);
//!   * `fingerprint` — steady state: the graph is uploaded once, every
//!                     later submit is a 16-byte reference.
//! The gap isolates what the fingerprint handshake saves per request at
//! each graph size — serialization + copy + validation of the topology.

use std::sync::Arc;
use std::time::{Duration, Instant};

use fused3s::coordinator::{Coordinator, CoordinatorConfig, ExecutorKind};
use fused3s::exec::ExecPolicy;
use fused3s::kernels::Backend;
use fused3s::graph::generators;
use fused3s::net::proto::csr_wire_bytes;
use fused3s::net::{NetClient, NetConfig, NetServer, WireRequest};
use fused3s::util::prng::Rng;

fn main() {
    let cfg = CoordinatorConfig {
        executor: ExecutorKind::HostEmulation,
        preprocess_workers: 2,
        queue_capacity: 64,
        max_batch_requests: 1,
        max_batch_delay: Duration::from_millis(100),
        cache_capacity: 32,
        exec: ExecPolicy::serial(),
        ..CoordinatorConfig::default()
    };
    let coord = match Coordinator::start(cfg) {
        Ok(c) => Arc::new(c),
        Err(e) => {
            eprintln!("net bench could not start a host coordinator: {e:#}");
            return;
        }
    };
    let server = match NetServer::serve(coord.clone(), NetConfig::default()) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("net bench could not bind loopback: {e:#}");
            coord.shutdown();
            return;
        }
    };
    let addr = server.local_addr();
    let full = std::env::var("F3S_BENCH_FULL").is_ok();
    let reps = if full { 64 } else { 16 };
    let d = 32;

    println!(
        "loopback round-trip, host emulation, d={d}, {reps} reps \
         (median µs/req):"
    );
    println!(
        "  {:<14} {:>12} {:>14} {:>10}",
        "graph", "inline", "fingerprint", "csr bytes"
    );
    for &n in &[256usize, 1024, 4096] {
        let g = generators::erdos_renyi(n, 8.0, n as u64).with_self_loops();
        let mut rng = Rng::new(0x5EED ^ n as u64);
        let nd = g.n * d;
        let q = rng.normal_vec(nd, 1.0);
        let k = rng.normal_vec(nd, 1.0);
        let v = rng.normal_vec(nd, 1.0);

        // Inline series: a fresh connection per rep, so the client's
        // known-set is empty and the CSR travels every time.
        let mut inline_us = Vec::with_capacity(reps);
        for r in 0..reps {
            let mut client = match NetClient::connect(addr, "") {
                Ok(c) => c,
                Err(e) => {
                    eprintln!("connect failed: {e}");
                    server.shutdown();
                    coord.shutdown();
                    return;
                }
            };
            let req = WireRequest::single_head(
                r as u64,
                &g,
                d,
                &q,
                &k,
                &v,
                0.125,
                Backend::CpuCsr,
            );
            let t0 = Instant::now();
            let ok = client.submit(&req).map(|r| r.result.is_ok());
            inline_us.push(t0.elapsed().as_secs_f64() * 1e6);
            client.close();
            if !matches!(ok, Ok(true)) {
                eprintln!("inline submit failed on {n}-node graph");
                server.shutdown();
                coord.shutdown();
                return;
            }
        }

        // Fingerprint series: one connection, warm the store with one
        // submit, then time the reference-only repeats.
        let mut client = NetClient::connect(addr, "").expect("connect");
        let warm = WireRequest::single_head(
            u64::MAX,
            &g,
            d,
            &q,
            &k,
            &v,
            0.125,
            Backend::CpuCsr,
        );
        let _ = client.submit(&warm);
        let mut fp_us = Vec::with_capacity(reps);
        for r in 0..reps {
            let req = WireRequest::single_head(
                r as u64,
                &g,
                d,
                &q,
                &k,
                &v,
                0.125,
                Backend::CpuCsr,
            );
            let t0 = Instant::now();
            let _ = client.submit(&req);
            fp_us.push(t0.elapsed().as_secs_f64() * 1e6);
        }
        client.close();

        inline_us.sort_by(|a, b| a.total_cmp(b));
        fp_us.sort_by(|a, b| a.total_cmp(b));
        println!(
            "  n={:<12} {:>10.1}us {:>12.1}us {:>10}",
            n,
            inline_us[inline_us.len() / 2],
            fp_us[fp_us.len() / 2],
            csr_wire_bytes(&g)
        );
    }
    println!();
    println!("{}", coord.metrics().report());
    server.shutdown();
    coord.shutdown();
}
