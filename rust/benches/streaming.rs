//! `cargo bench streaming` — incremental BSB maintenance under churn
//! (EXPERIMENTS.md §Streaming): delta-rebuild vs from-scratch build as a
//! function of the dirty-window fraction.
//!
//! Each churn level evolves the `er_2048` workload through 8 seeded edit
//! batches.  Two kinds of numbers come out:
//!
//! * **structural** (deterministic, machine-independent) — the dirty /
//!   spliced row-window fractions and the delta-vs-CSR wire-byte ratio.
//!   `scripts/streaming_model.py` replicates these in plain Python and
//!   must agree bit-for-bit; they are what `BENCH_streaming.json` pins.
//! * **timing** (informational, machine-scaled) — median wall time of
//!   `incremental::rebuild` vs `bsb::build` on the same patched graph,
//!   printed per level but *not* snapshotted (wall clock does not survive
//!   container changes; the structural fractions do).
//!
//! Gates (asserted): every incremental rebuild is bit-identical to the
//! from-scratch build, and the dirty fraction grows monotonically with
//! the edit rate.
//!
//! Env knobs: `F3S_BENCH_FULL=1` for full repeat counts.

use std::fmt::Write as _;
use std::path::Path;

use fused3s::bsb::{self, incremental};
use fused3s::graph::{generators, CsrGraph, GraphDelta};
use fused3s::net::proto::{csr_wire_bytes, delta_wire_bytes};
use fused3s::util::prng::Rng;
use fused3s::util::timing::{bench, BenchConfig};

const STEPS: usize = 8;
const SEED: u64 = 0xBEEF;
const EDIT_LEVELS: &[usize] = &[16, 64, 256, 1024];

/// Seeded mixed edit batch — kept in lockstep with
/// `scripts/streaming_model.py::churn()` (same RNG call order).
fn churn(g: &CsrGraph, edits: usize, rng: &mut Rng) -> (Vec<(u32, u32)>, Vec<(u32, u32)>) {
    let mut ins = Vec::new();
    let mut rem = Vec::new();
    for _ in 0..edits {
        if rng.coin(0.5) {
            let u = rng.below(g.n);
            let row = g.row(u);
            if !row.is_empty() {
                rem.push((u as u32, row[rng.below(row.len())]));
                continue;
            }
        }
        ins.push((rng.below(g.n) as u32, rng.below(g.n) as u32));
    }
    ins.retain(|e| !rem.contains(e));
    (ins, rem)
}

struct Row {
    edits: usize,
    dirty_rw_fraction: f64,
    spliced_fraction: f64,
    effective_inserts: usize,
    effective_removes: usize,
    delta_bytes_ratio: f64,
    incremental_ms: f64,
    scratch_ms: f64,
}

fn measure(base: &CsrGraph, edits: usize, cfg: &BenchConfig) -> Row {
    let mut rng = Rng::new(SEED);
    let mut g = base.clone();
    let mut old = bsb::build(&g);
    let num_rw = old.num_rw as u64;

    let mut dirtied = 0u64;
    let mut inserted = 0usize;
    let mut removed = 0usize;
    let mut delta_bytes = 0u64;
    let mut naive_bytes = 0u64;
    let mut last_patched = g.clone();
    let mut last_dirty: Vec<u32> = Vec::new();
    for _ in 0..STEPS {
        let (ins, rem) = churn(&g, edits, &mut rng);
        delta_bytes += delta_wire_bytes(ins.len(), rem.len());
        let delta = GraphDelta::against(&g, ins, rem);
        let (patched, report) = delta.applied(&g).expect("bench delta");
        naive_bytes += csr_wire_bytes(&patched);
        dirtied += report.dirty_rws.len() as u64;
        inserted += report.inserted;
        removed += report.removed;

        // Bit-identity gate on every step, not just the timed one.
        let (inc, stats) = incremental::rebuild(&old, &patched, &report.dirty_rws);
        let scratch = bsb::build(&patched);
        assert_eq!(inc, scratch, "edits={edits}: incremental BSB diverged");
        assert_eq!(stats.rebuilt, report.dirty_rws.len());
        old = inc;
        last_patched = patched.clone();
        last_dirty = report.dirty_rws.clone();
        g = patched;
    }

    // Time the final step's rebuild both ways (same inputs, same output).
    let prev = old.clone();
    let r_inc = bench(&format!("incremental e{edits}"), cfg, || {
        let (b, _) = incremental::rebuild(&prev, &last_patched, &last_dirty);
        assert_eq!(b.n, last_patched.n);
    });
    let r_scr = bench(&format!("scratch e{edits}"), cfg, || {
        let b = bsb::build(&last_patched);
        assert_eq!(b.n, last_patched.n);
    });

    let total = (num_rw * STEPS as u64) as f64;
    let dirty_rw_fraction = dirtied as f64 / total;
    Row {
        edits,
        dirty_rw_fraction,
        spliced_fraction: 1.0 - dirty_rw_fraction,
        effective_inserts: inserted,
        effective_removes: removed,
        delta_bytes_ratio: delta_bytes as f64 / naive_bytes as f64,
        incremental_ms: r_inc.median_ms(),
        scratch_ms: r_scr.median_ms(),
    }
}

fn main() {
    let full = std::env::var("F3S_BENCH_FULL").is_ok();
    let cfg = if full { BenchConfig::default() } else { BenchConfig::quick() };
    println!(
        "streaming: incremental rebuild vs from-scratch on er_2048, \
         {STEPS} steps per level (full={full})"
    );
    let base = generators::erdos_renyi(2048, 6.0, 7).with_self_loops();

    let mut rows = Vec::new();
    for &edits in EDIT_LEVELS {
        let row = measure(&base, edits, &cfg);
        let speedup = if row.incremental_ms > 0.0 {
            row.scratch_ms / row.incremental_ms
        } else {
            0.0
        };
        println!(
            "{{\"bench\":\"streaming\",\"edits_per_step\":{},\
             \"dirty_rw_fraction\":{:.6},\"spliced_fraction\":{:.6},\
             \"effective_inserts\":{},\"effective_removes\":{},\
             \"delta_bytes_ratio\":{:.6},\"incremental_ms\":{:.3},\
             \"scratch_ms\":{:.3},\"rebuild_speedup\":{speedup:.3}}}",
            row.edits,
            row.dirty_rw_fraction,
            row.spliced_fraction,
            row.effective_inserts,
            row.effective_removes,
            row.delta_bytes_ratio,
            row.incremental_ms,
            row.scratch_ms,
        );
        rows.push(row);
    }

    // More churn must dirty more windows (strictly, given these levels).
    for pair in rows.windows(2) {
        assert!(
            pair[0].dirty_rw_fraction < pair[1].dirty_rw_fraction,
            "dirty fraction must grow with the edit rate: {} vs {}",
            pair[0].dirty_rw_fraction,
            pair[1].dirty_rw_fraction
        );
    }

    // Snapshot the structural baseline (same schema as
    // scripts/streaming_model.py --write; timing fields excluded).
    let mut levels = String::new();
    let mut sorted: Vec<&Row> = rows.iter().collect();
    // Lexicographic key order, matching the model's sorted JSON dump.
    sorted.sort_by_key(|r| r.edits.to_string());
    for (i, row) in sorted.iter().enumerate() {
        if i > 0 {
            levels.push(',');
        }
        write!(
            levels,
            "\n  \"{}\": {{\n   \"delta_bytes_ratio\": {:.6},\n   \
             \"dirty_rw_fraction\": {:.6},\n   \"effective_inserts\": {},\n   \
             \"effective_removes\": {},\n   \"spliced_fraction\": {:.6}\n  }}",
            row.edits,
            row.delta_bytes_ratio,
            row.dirty_rw_fraction,
            row.effective_inserts,
            row.effective_removes,
            row.spliced_fraction,
        )
        .unwrap();
    }
    let payload = format!(
        "{{\n \"bench\": \"streaming\",\n \"config\": {{\n  \
         \"edit_levels\": {EDIT_LEVELS:?},\n  \"graph\": \"er_2048\",\n  \
         \"seed\": {SEED},\n  \"steps\": {STEPS}\n }},\n \
         \"levels\": {{{levels}\n }},\n \"unit\": \"row-window fractions and \
         wire-byte ratios (structure-only, no wall clock)\"\n}}\n",
    );
    let root = Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .expect("crate lives under the repo root");
    let path = root.join("BENCH_streaming.json");
    std::fs::write(&path, payload).expect("write BENCH_streaming.json");
    println!("wrote {}", path.display());
}
