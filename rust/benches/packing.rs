//! `cargo bench packing` — the padded-slot packing sweep (EXPERIMENTS.md
//! §Packing): hybrid geometry routing vs the 16-row all-wide reference
//! across four generator families, measured in **dispatched cells**, not
//! wall-clock.
//!
//! Everything here is integer plan arithmetic over deterministic graphs,
//! so the numbers are exactly reproducible and machine-independent —
//! `scripts/packing_model.py` replicates them in plain Python and must
//! agree.  Ratios are hybrid / wide-reference, i.e. normalized against the
//! serial reference plan shape rather than a timed run (ROADMAP item 4:
//! baselines must survive container changes).
//!
//! Prints one JSON row per graph and rewrites `BENCH_packing.json` at the
//! repo root.  Gates (asserted):
//!
//! * on the hub-skewed generators (star, power_law) the hybrid plan cuts
//!   padded cells by ≥ 30% vs the wide reference (the ISSUE 7 acceptance
//!   bar);
//! * on every graph the hybrid plan never dispatches more cells than the
//!   wide reference (the router only switches a window when strictly
//!   cheaper).

use std::fmt::Write as _;
use std::path::Path;

use fused3s::bsb::geometry::{self, RouteParams};
use fused3s::bsb::reorder::Order;
use fused3s::bsb::{self, Bsb};
use fused3s::graph::{generators, CsrGraph};

const BUCKETS: &[usize] = &[4, 8, 16, 32, 64, 128];
const BATCH: usize = 8;
const CHUNK_T: usize = 128;

/// The bench graphs — kept in lockstep with
/// `scripts/packing_model.py::bench_graphs()`.
fn bench_graphs() -> Vec<(&'static str, CsrGraph)> {
    vec![
        ("star_5000", generators::star(5000)),
        ("power_law_4096", generators::power_law(4096, 4.0, 2.5, 11)),
        ("er_2048", generators::erdos_renyi(2048, 6.0, 7).with_self_loops()),
        ("sbm_20x30", generators::sbm(20, 30, 0.4, 0.02, 4).with_self_loops()),
    ]
}

struct Row {
    name: &'static str,
    wide_dispatched: usize,
    wide_padded: usize,
    hybrid_dispatched: usize,
    hybrid_padded: usize,
    narrow_rws: usize,
    dense_rws: usize,
}

impl Row {
    fn padded_ratio(&self) -> f64 {
        if self.wide_padded == 0 {
            0.0
        } else {
            self.hybrid_padded as f64 / self.wide_padded as f64
        }
    }

    fn dispatched_ratio(&self) -> f64 {
        if self.wide_dispatched == 0 {
            0.0
        } else {
            self.hybrid_dispatched as f64 / self.wide_dispatched as f64
        }
    }
}

fn measure(name: &'static str, bsb: &Bsb) -> Row {
    // The 16-row reference: every window forced wide — the exact
    // pre-geometry plan shape, through the same planner code.
    let all_wide = RouteParams { narrow: false, dense: false, ..Default::default() };
    let wide = geometry::plan_hybrid_with(
        bsb,
        BUCKETS,
        BATCH,
        Order::ByTcbDesc,
        CHUNK_T,
        &all_wide,
    );
    let hybrid = geometry::plan_hybrid(bsb, BUCKETS, BATCH, Order::ByTcbDesc, CHUNK_T);
    Row {
        name,
        wide_dispatched: wide.stats.dispatched_cells(),
        wide_padded: wide.stats.padded_cells(),
        hybrid_dispatched: hybrid.stats.dispatched_cells(),
        hybrid_padded: hybrid.stats.padded_cells(),
        narrow_rws: hybrid.stats.narrow_windows,
        dense_rws: hybrid.stats.dense_windows,
    }
}

fn main() {
    println!("packing: hybrid geometry vs 16-row wide reference (structure-only)");
    let mut rows = Vec::new();
    for (name, g) in bench_graphs() {
        let bsb = bsb::build(&g);
        let row = measure(name, &bsb);
        println!(
            "{{\"bench\":\"packing\",\"graph\":\"{name}\",\
             \"wide_padded_cells\":{},\"hybrid_padded_cells\":{},\
             \"padded_cell_ratio\":{:.6},\
             \"wide_dispatched_cells\":{},\"hybrid_dispatched_cells\":{},\
             \"dispatched_cell_ratio\":{:.6},\
             \"narrow_rws\":{},\"dense_rws\":{}}}",
            row.wide_padded,
            row.hybrid_padded,
            row.padded_ratio(),
            row.wide_dispatched,
            row.hybrid_dispatched,
            row.dispatched_ratio(),
            row.narrow_rws,
            row.dense_rws,
        );
        assert!(
            row.hybrid_dispatched <= row.wide_dispatched,
            "{name}: hybrid dispatches MORE cells than the wide reference \
             ({} > {})",
            row.hybrid_dispatched,
            row.wide_dispatched
        );
        rows.push(row);
    }

    // Acceptance gate (ISSUE 7): ≥ 30% padded-cell reduction on the
    // hub-skewed generators.
    for name in ["star_5000", "power_law_4096"] {
        let row = rows.iter().find(|r| r.name == name).unwrap();
        let ratio = row.padded_ratio();
        assert!(
            ratio <= 0.70,
            "{name}: padded-cell ratio {ratio:.4} misses the ≥30% reduction \
             bar (padded {} vs wide {})",
            row.hybrid_padded,
            row.wide_padded
        );
    }

    // Snapshot the baseline at the repo root (same schema as
    // scripts/packing_model.py --write).
    let mut graphs = String::new();
    let mut sorted: Vec<&Row> = rows.iter().collect();
    sorted.sort_by_key(|r| r.name);
    for (i, row) in sorted.iter().enumerate() {
        if i > 0 {
            graphs.push(',');
        }
        write!(
            graphs,
            "\n  \"{}\": {{\n   \"dense_rws\": {},\n   \
             \"dispatched_cell_ratio\": {:.6},\n   \
             \"hybrid_dispatched_cells\": {},\n   \
             \"hybrid_padded_cells\": {},\n   \"narrow_rws\": {},\n   \
             \"padded_cell_ratio\": {:.6},\n   \
             \"wide_dispatched_cells\": {},\n   \"wide_padded_cells\": {}\n  }}",
            row.name,
            row.dense_rws,
            row.dispatched_ratio(),
            row.hybrid_dispatched,
            row.hybrid_padded,
            row.narrow_rws,
            row.padded_ratio(),
            row.wide_dispatched,
            row.wide_padded,
        )
        .unwrap();
    }
    let payload = format!(
        "{{\n \"bench\": \"packing\",\n \"config\": {{\n  \"batch\": {BATCH},\n  \
         \"buckets\": {BUCKETS:?},\n  \"chunk_t\": {CHUNK_T}\n }},\n \
         \"graphs\": {{{graphs}\n }},\n \"unit\": \"dispatched cells (ratios \
         are hybrid / wide-reference; structure-only, no wall clock)\"\n}}\n",
    );
    let root = Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .expect("crate lives under the repo root");
    let path = root.join("BENCH_packing.json");
    std::fs::write(&path, payload).expect("write BENCH_packing.json");
    println!("wrote {}", path.display());
}
