//! `cargo bench fault_overhead` — cost of the fault-injection seams on the
//! **disabled** hot path (EXPERIMENTS.md §Faults).
//!
//! The seams compile to one relaxed atomic load when no `FaultPlan` is
//! armed, and to nothing at all under `--no-default-features` (the
//! `fault-injection` feature is off).  This bench measures the fused host
//! path in three states inside one binary — disarmed and armed-zero-rate —
//! and prints whether the seams were compiled in, so a second run with
//! `--no-default-features` gives the compiled-out baseline for the same
//! workload.  Bit-exactness between all states is asserted before any row
//! prints: the instrumentation must not perturb the arithmetic.
//!
//! Env knobs: `F3S_BENCH_FULL=1` for full iteration counts,
//! `F3S_FAULT_BENCH_N=<n>` to shrink the graph for smoke runs.

use fused3s::exec::{offline_manifest, Engine, ExecPolicy};
use fused3s::fault::{self, FaultPlan};
use fused3s::graph::generators;
use fused3s::kernels::{AttentionBatch, AttentionProblem, Backend, ExecCtx, Plan};
use fused3s::util::prng::Rng;
use fused3s::util::timing::{bench, BenchConfig};

const BUCKETS: &[usize] = &[4, 8, 16, 32, 64, 128];

fn main() {
    let full = std::env::var("F3S_BENCH_FULL").is_ok();
    let n: usize = std::env::var("F3S_FAULT_BENCH_N")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(2048);
    let deg = 8.0;
    let d = 32;
    let cfg = if full { BenchConfig::default() } else { BenchConfig::quick() };
    let compiled = cfg!(feature = "fault-injection");

    println!(
        "fault_overhead: erdos_renyi({n}, {deg}) d={d} \
         (full={full}, seams_compiled={compiled})"
    );
    let g = generators::erdos_renyi(n, deg, 1).with_self_loops();
    let mut rng = Rng::new(2);
    let q = rng.normal_vec(n * d, 1.0);
    let k = rng.normal_vec(n * d, 1.0);
    let v = rng.normal_vec(n * d, 1.0);
    let x = AttentionProblem::new(n, d, &q, &k, &v, 0.125);
    let batch = AttentionBatch::single(&x);
    let man = offline_manifest(32, BUCKETS, 128);
    let engine = Engine::new(ExecPolicy { threads: 4, pipeline_depth: 2 });
    let plan = Plan::new(&man, &g, Backend::Fused3S, &engine).expect("plan");

    let run = || {
        plan.execute(&mut ExecCtx::host(&engine), &batch)
            .expect("run")
    };

    // Bit-exactness gate first: neither the disarmed seams nor an armed
    // zero-rate plan may change a single bit of the output.
    let want = run();
    {
        let _guard = fault::install(FaultPlan::uniform(7, 0.0));
        assert_eq!(run(), want, "armed zero-rate run diverged");
    }
    assert_eq!(run(), want, "disarmed run diverged");

    let disarmed = bench("disarmed", &cfg, || {
        assert_eq!(run().len(), n * d);
    });
    let armed = {
        let _guard = fault::install(FaultPlan::uniform(7, 0.0));
        bench("armed zero-rate", &cfg, || {
            assert_eq!(run().len(), n * d);
        })
    };
    let ratio = if disarmed.median_ms() > 0.0 {
        armed.median_ms() / disarmed.median_ms()
    } else {
        1.0
    };
    println!(
        "{{\"bench\":\"fault_overhead\",\"n\":{n},\"deg\":{deg},\"d\":{d},\
         \"seams_compiled\":{compiled},\
         \"disarmed_ms\":{:.3},\"armed_zero_rate_ms\":{:.3},\
         \"armed_over_disarmed\":{ratio:.4},\"bit_identical\":true}}",
        disarmed.median_ms(),
        armed.median_ms(),
    );
    println!("  {}", disarmed.row());
    println!("  {}", armed.row());
    println!(
        "  armed/disarmed median ratio: {ratio:.4} \
         (re-run with --no-default-features for the compiled-out baseline)"
    );
}
