//! `cargo bench coordinator` — serving-loop throughput/latency under a
//! synthetic multi-graph request stream (the reproduction's L3 service
//! path; not a paper figure, but the deployment story the stack exists
//! for).  Also reports gather/scatter and bucket-planning microbenches.

use fused3s::bsb;
use fused3s::bsb::bucket;
use fused3s::bsb::reorder::Order;
use fused3s::coordinator::{AttnRequest, Coordinator, CoordinatorConfig};
use fused3s::graph::{datasets, generators};
use fused3s::kernels::Backend;
use fused3s::util::prng::Rng;
use fused3s::util::timing::{bench, BenchConfig};
use std::sync::mpsc::channel;

fn main() {
    // Microbench: bucket planning.
    let cfg = BenchConfig::quick();
    println!("bucket planning (BSB -> dispatch plan):");
    for name in ["pubmed-sim", "github-sim", "reddit-sim"] {
        let d = datasets::by_name(name).expect("dataset");
        let b = bsb::build(&d.graph);
        let r = bench(name, &cfg, || {
            let p = bucket::plan(&b, &[4, 8, 16, 32, 64, 128], 32, Order::ByTcbDesc, 128);
            std::hint::black_box(p.stats.n_calls);
        });
        println!("  {:<14} {:>8.3} ms", name, r.median_ms());
    }

    // End-to-end serving throughput.
    let coord = match Coordinator::start(CoordinatorConfig::default()) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("serving bench requires artifacts: {e:#}");
            return;
        }
    };
    let n_req = if std::env::var("F3S_BENCH_FULL").is_ok() { 64 } else { 16 };
    let d = 64;
    let mut rng = Rng::new(0xBE9C);
    let (tx, rx) = channel();
    let t0 = std::time::Instant::now();
    for i in 0..n_req {
        let n = rng.range(128, 768);
        let g = generators::erdos_renyi(n, 4.0, i as u64).with_self_loops();
        let nd = g.n * d;
        coord
            .submit(AttnRequest::single_head(
                i as u64,
                g,
                d,
                rng.normal_vec(nd, 1.0),
                rng.normal_vec(nd, 1.0),
                rng.normal_vec(nd, 1.0),
                0.125,
                Backend::Fused3S,
                tx.clone(),
            ))
            .expect("submit");
    }
    drop(tx);
    let mut ok = 0;
    while let Ok(r) = rx.recv() {
        if r.result.is_ok() {
            ok += 1;
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    println!("\nserving: {ok}/{n_req} ok in {wall:.2}s ({:.1} req/s)", ok as f64 / wall);
    println!("{}", coord.metrics().report());
    coord.shutdown();
}
