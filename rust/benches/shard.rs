//! `cargo bench shard` — the partition-parallel sweep (EXPERIMENTS.md
//! §Sharding): sharded vs unsharded execution across shard counts and
//! workload families, through the offline host pipeline (no artifacts).
//!
//! For each (generator × shard count) the bench builds a TCB-balanced
//! [`ShardedPlan`], checks its output **bit-identical** to the unsharded
//! plan, then times both and reports the realised halo fraction
//! (replicated K/V rows ÷ n) next to the latency — the replication-vs-
//! working-set trade the planner's sharded cost candidate models.  One
//! JSON row per combination.  Env knobs: `F3S_BENCH_FULL=1` for full
//! sizes/iterations.

use fused3s::exec::{offline_manifest, Engine, ExecPolicy};
use fused3s::graph::{generators, CsrGraph};
use fused3s::kernels::{AttentionBatch, Backend, ExecCtx, Plan};
use fused3s::planner::DEFAULT_BUCKETS;
use fused3s::shard::{ShardPolicy, ShardedPlan};
use fused3s::util::prng::Rng;
use fused3s::util::timing::{bench, BenchConfig};

const SHARD_COUNTS: &[usize] = &[1, 2, 4, 8];

fn workloads(full: bool) -> Vec<(&'static str, CsrGraph)> {
    let n = if full { 16384 } else { 4096 };
    vec![
        ("er", generators::erdos_renyi(n, 8.0, 61).with_self_loops()),
        (
            "power_law",
            generators::power_law(n, 8.0, 2.4, 62).with_self_loops(),
        ),
        ("star", generators::star(n).with_self_loops()),
        (
            "sbm",
            generators::sbm(n / 128, 128, 0.05, 0.0005, 63).with_self_loops(),
        ),
    ]
}

fn main() {
    let full = std::env::var("F3S_BENCH_FULL").is_ok();
    let cfg = if full { BenchConfig::default() } else { BenchConfig::quick() };
    let d = 32usize;
    let man = offline_manifest(8, DEFAULT_BUCKETS, 128);
    let engine = Engine::new(ExecPolicy { threads: 4, pipeline_depth: 2 });

    println!("shard: sharded vs unsharded, TCB-balanced partitions (full={full})");
    for (gen, g) in workloads(full) {
        let n = g.n;
        let mut rng = Rng::new(0x54A2);
        let q = rng.normal_vec(n * d, 1.0);
        let k = rng.normal_vec(n * d, 1.0);
        let v = rng.normal_vec(n * d, 1.0);
        let scale = 1.0 / (d as f32).sqrt();
        let x = AttentionBatch::new(n, d, d, 1, &q, &k, &v, scale);

        let plain =
            Plan::new(&man, &g, Backend::Fused3S, &engine).expect("plan");
        let want = plain
            .execute(&mut ExecCtx::host(&engine), &x)
            .expect("unsharded executes");
        let r = bench("unsharded", &cfg, || {
            let o = plain
                .execute(&mut ExecCtx::host(&engine), &x)
                .expect("unsharded executes");
            assert_eq!(o.len(), n * d);
        });
        let base_ms = r.median_ms();
        println!(
            "{{\"bench\":\"shard\",\"generator\":\"{gen}\",\"n\":{n},\
             \"shards\":1,\"mode\":\"unsharded\",\"ms\":{base_ms:.3}}}"
        );

        for &shards in SHARD_COUNTS {
            let sp = ShardedPlan::new(
                &man,
                &g,
                Backend::Fused3S,
                &engine,
                ShardPolicy::balanced(shards),
            )
            .expect("sharded plan");
            let halo = sp.halo_fraction();
            let got = sp
                .execute(&mut ExecCtx::host(&engine), &x)
                .expect("sharded executes");
            // Bit-exactness gate before anything is timed.
            assert_eq!(
                got, want,
                "{gen} shards={shards}: sharded output diverged"
            );
            let r = bench("sharded", &cfg, || {
                let o = sp
                    .execute(&mut ExecCtx::host(&engine), &x)
                    .expect("sharded executes");
                assert_eq!(o.len(), n * d);
            });
            let ms = r.median_ms();
            let stats = sp.stats();
            println!(
                "{{\"bench\":\"shard\",\"generator\":\"{gen}\",\"n\":{n},\
                 \"shards\":{},\"mode\":\"sharded\",\"ms\":{ms:.3},\
                 \"halo_fraction\":{halo:.4},\"halo_rows\":{},\
                 \"local_nodes\":{},\"vs_unsharded\":{:.3}}}",
                stats.shards,
                stats.halo_rows,
                stats.local_nodes,
                ms / base_ms.max(1e-9),
            );
        }
    }
}
