//! `cargo bench formats` — Table 3 (format footprints) + BSB construction
//! throughput (the preprocessing cost the paper calls "negligible").

use fused3s::bsb;
use fused3s::experiments::{report, table3};
use fused3s::graph::datasets;
use fused3s::util::timing::{bench, BenchConfig};

fn main() {
    let j = table3::run(None).expect("table3");
    report::write_json("bench_formats", &j).expect("write json");

    println!("\nBSB construction throughput (preprocessing cost):");
    let cfg = BenchConfig::quick();
    for d in datasets::suite_single() {
        let r = bench(d.name, &cfg, || {
            let b = bsb::build(&d.graph);
            std::hint::black_box(b.total_tcbs());
        });
        let meps = d.graph.nnz() as f64 / r.median_s / 1e6;
        println!(
            "  {:<22} {:>8.2} ms  ({:>7.1} M edges/s)",
            d.name,
            r.median_ms(),
            meps
        );
    }
}
