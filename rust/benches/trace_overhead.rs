//! `cargo bench trace_overhead` — cost of the tracing seams
//! (EXPERIMENTS.md §Tracing, DESIGN.md §15).
//!
//! The seams compile to one relaxed atomic load when no tracer is armed,
//! and to nothing at all under `--no-default-features` (the `tracing`
//! feature is off).  This bench measures the fused host path in three
//! states inside one binary — disarmed, armed-but-unsampled (the request
//! rolled 0, every hook short-circuits on the zero span), and
//! armed-recording at `sample_rate = 1.0` inside a live span, where the
//! engine stage seams actually write ring slots.  Bit-exactness between
//! all states is asserted before any row prints: the instrumentation
//! must not perturb the arithmetic.
//!
//! Env knobs: `F3S_BENCH_FULL=1` for full iteration counts,
//! `F3S_TRACE_BENCH_N=<n>` to shrink the graph for smoke runs.

use fused3s::exec::{offline_manifest, Engine, ExecPolicy};
use fused3s::graph::generators;
use fused3s::kernels::{AttentionBatch, AttentionProblem, Backend, ExecCtx, Plan};
use fused3s::trace::{self, TraceConfig};
use fused3s::util::prng::Rng;
use fused3s::util::timing::{bench, BenchConfig};

const BUCKETS: &[usize] = &[4, 8, 16, 32, 64, 128];

fn main() {
    let full = std::env::var("F3S_BENCH_FULL").is_ok();
    let n: usize = std::env::var("F3S_TRACE_BENCH_N")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(2048);
    let deg = 8.0;
    let d = 32;
    let cfg = if full { BenchConfig::default() } else { BenchConfig::quick() };
    let compiled = cfg!(feature = "tracing");

    println!(
        "trace_overhead: erdos_renyi({n}, {deg}) d={d} \
         (full={full}, tracing_compiled={compiled})"
    );
    let g = generators::erdos_renyi(n, deg, 1).with_self_loops();
    let mut rng = Rng::new(2);
    let q = rng.normal_vec(n * d, 1.0);
    let k = rng.normal_vec(n * d, 1.0);
    let v = rng.normal_vec(n * d, 1.0);
    let x = AttentionProblem::new(n, d, &q, &k, &v, 0.125);
    let batch = AttentionBatch::single(&x);
    let man = offline_manifest(32, BUCKETS, 128);
    let engine = Engine::new(ExecPolicy { threads: 4, pipeline_depth: 2 });
    let plan = Plan::new(&man, &g, Backend::Fused3S, &engine).expect("plan");

    let run = || {
        plan.execute(&mut ExecCtx::host(&engine), &batch)
            .expect("run")
    };

    // Bit-exactness gate first: neither the disarmed seams nor a fully
    // recording tracer may change a single bit of the output.
    let want = run();
    {
        let guard = trace::install(TraceConfig::default());
        let span = guard.sample_request(1);
        assert_ne!(span, 0, "rate 1.0 must sample");
        assert_eq!(
            trace::with_span(span, run),
            want,
            "recording run diverged"
        );
        assert!(guard.recorded() > 0, "recording run traced nothing");
    }
    assert_eq!(run(), want, "disarmed run diverged");

    let disarmed = bench("disarmed", &cfg, || {
        assert_eq!(run().len(), n * d);
    });
    let (unsampled, recording) = {
        let guard = trace::install(TraceConfig::default());
        // Unsampled: the hooks see span 0 and bail before the ring.
        let unsampled = bench("armed unsampled", &cfg, || {
            assert_eq!(run().len(), n * d);
        });
        let span = guard.sample_request(2);
        let recording = bench("armed recording", &cfg, || {
            assert_eq!(trace::with_span(span, run).len(), n * d);
        });
        (unsampled, recording)
    };
    let ratio = if disarmed.median_ms() > 0.0 {
        unsampled.median_ms() / disarmed.median_ms()
    } else {
        1.0
    };
    let rec_ratio = if disarmed.median_ms() > 0.0 {
        recording.median_ms() / disarmed.median_ms()
    } else {
        1.0
    };
    println!(
        "{{\"bench\":\"trace_overhead\",\"n\":{n},\"deg\":{deg},\"d\":{d},\
         \"tracing_compiled\":{compiled},\
         \"disarmed_ms\":{:.3},\"armed_unsampled_ms\":{:.3},\
         \"armed_recording_ms\":{:.3},\
         \"armed_over_disarmed\":{ratio:.4},\
         \"recording_over_disarmed\":{rec_ratio:.4},\
         \"bit_identical\":true}}",
        disarmed.median_ms(),
        unsampled.median_ms(),
        recording.median_ms(),
    );
    println!("  {}", disarmed.row());
    println!("  {}", unsampled.row());
    println!("  {}", recording.row());
    println!(
        "  armed(unsampled)/disarmed median ratio: {ratio:.4} \
         (re-run with --no-default-features for the compiled-out baseline)"
    );
}
