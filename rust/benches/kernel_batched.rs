//! `cargo bench kernel_batched` — Figure 6: 3S kernel comparison on the
//! batched-graph suites (LRGB/OGB analogs, block-diagonal sparsity).

use fused3s::experiments::{fig5, report};
use fused3s::graph::datasets;
use fused3s::kernels::Backend;
use fused3s::runtime::Runtime;
use fused3s::util::timing::BenchConfig;

fn main() {
    let full = std::env::var("F3S_BENCH_FULL").is_ok();
    let rt = match Runtime::from_default_artifacts() {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("bench requires artifacts (`make artifacts`): {e:#}");
            return;
        }
    };
    let suite: Vec<_> = if full {
        datasets::suite_batched()
    } else {
        datasets::suite_batched()
            .into_iter()
            .filter(|d| d.name == "molhiv-sim" || d.name == "peptides-func-sim")
            .collect()
    };
    let cfg = if full { BenchConfig::default() } else { BenchConfig::quick() };
    let j = fig5::run(&rt, &suite, &Backend::kernel_series(), 64, &cfg, "fig6")
        .expect("fig6 bench");
    let p = report::write_json("bench_kernel_batched", &j).expect("write json");
    println!("wrote {}", p.display());
}
