//! `cargo bench coordinator_batching` — the dynamic-batching sweep
//! (EXPERIMENTS.md §Batching): the full coordinator serving path in
//! `HostEmulation` mode (no artifacts needed) under a molecule-vocabulary
//! request stream, swept over `max_batch_delay` × `max_batch_nodes`, plus
//! a no-batching baseline (`max_batch_requests = 1`).
//!
//! Prints one JSON row per config (machine-readable for the BENCH_*
//! trajectory).  Env knobs: `F3S_BENCH_FULL=1` for the full request count.

use std::sync::mpsc::channel;
use std::time::{Duration, Instant};

use fused3s::coordinator::{
    AttnRequest, Coordinator, CoordinatorConfig, ExecutorKind,
};
use fused3s::exec::ExecPolicy;
use fused3s::graph::batch::random_molecule;
use fused3s::graph::CsrGraph;
use fused3s::kernels::Backend;
use fused3s::util::prng::Rng;

const D: usize = 32;

fn main() {
    let full = std::env::var("F3S_BENCH_FULL").is_ok();
    let requests = if full { 256 } else { 48 };

    // A fixed vocabulary of molecule-like structures: the serving steady
    // state repeats graphs, which is what the fingerprint cache exploits.
    let mut rng = Rng::new(0xBA7C);
    let vocab: Vec<CsrGraph> = (0..12)
        .map(|_| {
            let n = rng.range(20, 90);
            random_molecule(n, &mut rng).with_self_loops()
        })
        .collect();

    println!(
        "coordinator_batching: {requests} requests, d={D}, vocab={} \
         molecule graphs (full={full})",
        vocab.len()
    );

    // Baseline: dynamic batching off.
    run_config(&vocab, requests, 0, 16384, 1);
    // The sweep: delay × node budget.
    for &delay_us in &[0u64, 200, 1000] {
        for &max_nodes in &[512usize, 2048, 8192] {
            run_config(&vocab, requests, delay_us, max_nodes, 64);
        }
    }
}

fn run_config(
    vocab: &[CsrGraph],
    requests: usize,
    delay_us: u64,
    max_nodes: usize,
    max_requests: usize,
) {
    let coord = Coordinator::start(CoordinatorConfig {
        executor: ExecutorKind::HostEmulation,
        preprocess_workers: 2,
        queue_capacity: 64,
        exec: ExecPolicy { threads: 4, pipeline_depth: 2 },
        max_batch_requests: max_requests,
        max_batch_nodes: max_nodes,
        max_batch_delay: Duration::from_micros(delay_us),
        cache_capacity: 64,
        ..CoordinatorConfig::default()
    })
    .expect("host-emulation coordinator");

    let mut rng = Rng::new(7);
    let (tx, rx) = channel();
    let t0 = Instant::now();
    for i in 0..requests {
        let g = vocab[rng.below(vocab.len())].clone();
        let nd = g.n * D;
        coord
            .submit(AttnRequest::single_head(
                i as u64,
                g,
                D,
                rng.normal_vec(nd, 1.0),
                rng.normal_vec(nd, 1.0),
                rng.normal_vec(nd, 1.0),
                0.125,
                Backend::Fused3S,
                tx.clone(),
            ))
            .expect("submit");
    }
    drop(tx);
    let mut ok = 0usize;
    for _ in 0..requests {
        match rx.recv_timeout(Duration::from_secs(120)) {
            Ok(resp) if resp.result.is_ok() => ok += 1,
            Ok(resp) => panic!("request {} failed: {:?}", resp.id, resp.result.err()),
            Err(e) => panic!("response timeout: {e}"),
        }
    }
    let wall_s = t0.elapsed().as_secs_f64();
    let m = coord.metrics();
    let lat = m.latency.snapshot();
    let b = &m.batching;
    println!(
        "{{\"bench\":\"coordinator_batching\",\"delay_us\":{delay_us},\
         \"max_nodes\":{max_nodes},\"max_requests\":{max_requests},\
         \"requests\":{requests},\"ok\":{ok},\"wall_ms\":{:.3},\
         \"throughput_rps\":{:.2},\"p50_ms\":{:.3},\"p99_ms\":{:.3},\
         \"batches\":{},\"coalesced\":{},\"largest_batch\":{},\
         \"cache_hits\":{},\"cache_misses\":{}}}",
        wall_s * 1e3,
        ok as f64 / wall_s,
        lat.p50_s * 1e3,
        lat.p99_s * 1e3,
        b.batches(),
        b.coalesced_requests(),
        b.largest_batch(),
        b.cache_hits(),
        b.cache_misses(),
    );
    coord.shutdown();
}
