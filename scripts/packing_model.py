#!/usr/bin/env python3
"""Reference model of the hybrid-geometry packing arithmetic.

Replicates, in plain Python, the deterministic pieces the packing bench
(`rust/benches/packing.rs`) exercises:

* the repo PRNG (`util::prng::Rng` — splitmix64-seeded xoshiro256**),
* the graph generators it feeds (star / power_law / erdos_renyi / sbm),
* per-row-window shape extraction (`bsb::geometry::WindowShape`),
* the router (`bsb::geometry::route`) and the PlanStats cell accounting of
  both the 16-row wide reference plan (`bsb::bucket::plan`) and the hybrid
  plan (`bsb::geometry::plan_hybrid`).

Everything here is integer plan arithmetic over deterministic graphs — no
timing — so the numbers are exactly reproducible and machine-independent.
`python3 scripts/packing_model.py` prints the per-graph table and rewrites
`BENCH_packing.json` at the repo root when run with `--write`; the Rust
bench computes the same quantities natively and must agree (EXPERIMENTS.md
§Packing documents the contract).
"""

import json
import math
import os
import sys

MASK = (1 << 64) - 1

# --- util::prng::Rng ------------------------------------------------------


class Rng:
    """xoshiro256** with splitmix64 seeding (bit-exact vs util/prng.rs)."""

    def __init__(self, seed):
        s = seed & MASK
        self.s = []
        for _ in range(4):
            s = (s + 0x9E3779B97F4A7C15) & MASK
            z = s
            z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & MASK
            z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & MASK
            self.s.append(z ^ (z >> 31))

    def next_u64(self):
        s = self.s
        result = (self._rotl((s[1] * 5) & MASK, 7) * 9) & MASK
        t = (s[1] << 17) & MASK
        s[2] ^= s[0]
        s[3] ^= s[1]
        s[1] ^= s[2]
        s[0] ^= s[3]
        s[2] ^= t
        s[3] = self._rotl(s[3], 45)
        return result

    @staticmethod
    def _rotl(x, k):
        return ((x << k) | (x >> (64 - k))) & MASK

    def f64(self):
        return (self.next_u64() >> 11) * (1.0 / (1 << 53))

    def below(self, n):
        # Lemire's unbiased bounded sampling, as in Rng::below.
        x = self.next_u64()
        m = x * n
        low = m & MASK
        if low < n:
            t = ((1 << 64) - n) % n
            while low < t:
                x = self.next_u64()
                m = x * n
                low = m & MASK
        return m >> 64

    def coin(self, p):
        return self.f64() < p


# --- graph::generators (the subset the packing bench uses) ----------------


def from_edges(n, edges):
    adj = [[] for _ in range(n)]
    for u, v in edges:
        adj[u].append(v)
    return [sorted(set(row)) for row in adj]


def with_self_loops(adj):
    return [sorted(set(row) | {i}) for i, row in enumerate(adj)]


def star(n):
    edges = []
    for v in range(1, n):
        edges.append((0, v))
        edges.append((v, 0))
    return from_edges(n, edges)


def erdos_renyi(n, avg_deg, seed):
    rng = Rng(seed)
    edges = []
    base = int(math.floor(avg_deg))
    frac = avg_deg - math.floor(avg_deg)
    for u in range(n):
        deg = base + (1 if rng.coin(frac) else 0)
        for _ in range(deg):
            edges.append((u, rng.below(n)))
    return from_edges(n, edges)


def power_law(n, avg_deg, alpha, seed):
    gamma = 1.0 / (alpha - 1.0)
    cum = []
    acc = 0.0
    for i in range(n):
        acc += (i + 1) ** (-gamma)
        cum.append(acc)
    total = acc
    rng = Rng(seed)
    m = round(n * avg_deg / 2.0)

    def pick():
        r = rng.f64() * total
        # partition_point(|&c| c < r)
        lo, hi = 0, n
        while lo < hi:
            mid = (lo + hi) // 2
            if cum[mid] < r:
                lo = mid + 1
            else:
                hi = mid
        return min(lo, n - 1)

    edges = []
    for _ in range(m):
        u = pick()
        v = pick()
        if u != v:
            edges.append((u, v))
            edges.append((v, u))
    return from_edges(n, edges)


def sbm(blocks, block_size, p_in, p_out, seed):
    n = blocks * block_size
    rng = Rng(seed)
    edges = []
    deg_in = round(p_in * block_size)
    deg_out = round(p_out * (n - block_size))
    for u in range(n):
        bu = u // block_size
        for _ in range(deg_in):
            edges.append((u, bu * block_size + rng.below(block_size)))
        for _ in range(deg_out):
            v = rng.below(n)
            if v // block_size == bu:
                v = (v + block_size) % n
            edges.append((u, v))
    return from_edges(n, edges)


# --- bsb::geometry shapes + router ----------------------------------------

TCB_R = 16
TCB_C = 8
WIDE_TCB_CELLS = TCB_R * TCB_C
NARROW_TILE_CELLS = TCB_R // 2
DENSE_LANE_CELLS = TCB_R
NARROW_ROWS = TCB_R // 2
NARROW_BUCKETS = [8, 16, 32, 64, 128, 256, 512, 1024]
DENSE_OCCUPANCY = 0.5

BUCKETS = [4, 8, 16, 32, 64, 128]
BATCH = 8
CHUNK_T = 128


def window_shapes(adj):
    n = len(adj)
    shapes = []
    for base in range(0, n, TCB_R):
        rows = min(TCB_R, n - base)
        cols = set()
        half0 = set()
        half1 = set()
        z = 0
        for r in range(base, base + rows):
            row = adj[r]
            z += len(row)
            cols.update(row)
            if r - base < NARROW_ROWS:
                half0.update(row)
            else:
                half1.update(row)
        shapes.append(
            {"rows": rows, "w": len(cols), "w0": len(half0), "w1": len(half1), "z": z}
        )
    return shapes


def bucket_ceil(buckets, t):
    for b in buckets:
        if b >= t:
            return b
    return None


def narrow_half_tiles(w_half):
    if w_half == 0:
        return 0
    return bucket_ceil(NARROW_BUCKETS, w_half)


def dense_width(w):
    return -(-w // TCB_C) * TCB_C


def route(s, narrow=True, dense=True):
    if s["z"] == 0:
        return "wide"
    t = -(-s["w"] // TCB_C)
    b = bucket_ceil(BUCKETS, t)
    if b is None:
        return "wide"  # oversize -> chunked, always wide
    wide_cells = b * WIDE_TCB_CELLS
    best = (wide_cells, "wide")
    if dense:
        occ = s["z"] / (s["rows"] * s["w"])
        if occ >= DENSE_OCCUPANCY:
            c = dense_width(s["w"]) * DENSE_LANE_CELLS
            if c < best[0]:
                best = (c, "dense")
    if narrow:
        t0 = narrow_half_tiles(s["w0"])
        t1 = narrow_half_tiles(s["w1"])
        if t0 is not None and t1 is not None:
            c = (t0 + t1) * NARROW_TILE_CELLS
            if c < best[0]:
                best = (c, "narrow")
    return best[1]


# --- PlanStats cell accounting (bucket::plan / geometry::plan_hybrid) -----


def wide_plan_cells(shapes, keep=None):
    """(dispatched_cells, padded_cells) of bucket::plan over `keep` RWs."""
    real = padded = slot_tcbs = 0
    per_bucket = {}
    total_chunks = 0
    for i, s in enumerate(shapes):
        if keep is not None and not keep[i]:
            continue
        if s["z"] == 0:
            continue
        t = -(-s["w"] // TCB_C)
        b = bucket_ceil(BUCKETS, t)
        real += t
        if b is None:
            chunks = -(-t // CHUNK_T)
            total_chunks += chunks
            padded += chunks * CHUNK_T - t
        else:
            padded += b - t
            per_bucket[b] = per_bucket.get(b, 0) + 1
    for b, count in per_bucket.items():
        rem = count % BATCH
        if rem:
            slot_tcbs += (BATCH - rem) * b
    rem = total_chunks % BATCH
    if rem:
        slot_tcbs += (BATCH - rem) * CHUNK_T
    dispatched = (real + padded + slot_tcbs) * WIDE_TCB_CELLS
    padded_cells = (padded + slot_tcbs) * WIDE_TCB_CELLS
    return dispatched, padded_cells


def hybrid_plan_cells(shapes):
    """(dispatched_cells, padded_cells, routes) of geometry::plan_hybrid."""
    routes = [route(s) for s in shapes]
    keep = [r == "wide" for r in routes]
    disp, pad = wide_plan_cells(shapes, keep)

    # Narrow path: per half-window tile-bucket batching.
    real_tiles = pad_tiles = slot_tiles = 0
    per_bucket = {}
    for s, r in zip(shapes, routes):
        if r != "narrow":
            continue
        for w_half in (s["w0"], s["w1"]):
            if w_half == 0:
                continue
            b = narrow_half_tiles(w_half)
            real_tiles += w_half
            pad_tiles += b - w_half
            per_bucket[b] = per_bucket.get(b, 0) + 1
    for b, count in per_bucket.items():
        rem = count % BATCH
        if rem:
            slot_tiles += (BATCH - rem) * b
    disp += (real_tiles + pad_tiles + slot_tiles) * NARROW_TILE_CELLS
    pad += (pad_tiles + slot_tiles) * NARROW_TILE_CELLS

    # Dense path: per padded-width batching.
    cols = pad_cols = slot_cols = 0
    per_width = {}
    for s, r in zip(shapes, routes):
        if r != "dense":
            continue
        w = s["w"]
        width = dense_width(w)
        cols += w
        pad_cols += width - w
        per_width[width] = per_width.get(width, 0) + 1
    for width, count in per_width.items():
        rem = count % BATCH
        if rem:
            slot_cols += (BATCH - rem) * width
    disp += (cols + pad_cols + slot_cols) * DENSE_LANE_CELLS
    pad += (pad_cols + slot_cols) * DENSE_LANE_CELLS
    return disp, pad, routes


# --- the bench graphs ------------------------------------------------------


def bench_graphs():
    return [
        ("star_5000", star(5000)),
        ("power_law_4096", power_law(4096, 4.0, 2.5, 11)),
        ("er_2048", with_self_loops(erdos_renyi(2048, 6.0, 7))),
        ("sbm_20x30", with_self_loops(sbm(20, 30, 0.4, 0.02, 4))),
    ]


def main():
    write = "--write" in sys.argv
    results = {}
    print(f"{'graph':<16} {'wide_pad':>10} {'hyb_pad':>10} {'pad_ratio':>9} "
          f"{'wide_disp':>11} {'hyb_disp':>11} {'disp_ratio':>10} {'nar':>5} {'den':>5}")
    for name, adj in bench_graphs():
        shapes = window_shapes(adj)
        wd, wp = wide_plan_cells(shapes)
        hd, hp, routes = hybrid_plan_cells(shapes)
        pad_ratio = hp / wp if wp else 0.0
        disp_ratio = hd / wd if wd else 0.0
        nar = sum(1 for r in routes if r == "narrow")
        den = sum(1 for r in routes if r == "dense")
        print(f"{name:<16} {wp:>10} {hp:>10} {pad_ratio:>9.4f} "
              f"{wd:>11} {hd:>11} {disp_ratio:>10.4f} {nar:>5} {den:>5}")
        results[name] = {
            "wide_padded_cells": wp,
            "hybrid_padded_cells": hp,
            "padded_cell_ratio": round(pad_ratio, 6),
            "wide_dispatched_cells": wd,
            "hybrid_dispatched_cells": hd,
            "dispatched_cell_ratio": round(disp_ratio, 6),
            "narrow_rws": nar,
            "dense_rws": den,
        }
    if write:
        root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
        path = os.path.join(root, "BENCH_packing.json")
        payload = {
            "bench": "packing",
            "unit": "dispatched cells (ratios are hybrid / wide-reference; "
                    "structure-only, no wall clock)",
            "config": {"buckets": BUCKETS, "batch": BATCH, "chunk_t": CHUNK_T},
            "graphs": results,
        }
        with open(path, "w") as f:
            json.dump(payload, f, indent=1, sort_keys=True)
            f.write("\n")
        print(f"wrote {path}")


if __name__ == "__main__":
    main()
