#!/usr/bin/env python3
"""Reference model of the streaming-churn structural arithmetic.

Replicates, in plain Python, the deterministic pieces the streaming bench
(`rust/benches/streaming.rs`) snapshots:

* the repo PRNG and the `er_2048` generator (imported from
  `packing_model.py` — bit-exact vs `util/prng.rs` / `graph/generators.rs`),
* the seeded `churn()` edit-batch sampler (same RNG call order as the
  bench's Rust copy),
* `GraphDelta::apply`'s effective-change accounting: no-op-filtered
  insert/remove counts and the dirty row-window set (per-row membership
  diff, windows of 16 rows),
* the wire cost model (`net::proto::delta_wire_bytes` vs
  `csr_wire_bytes`).

Everything is integer/set arithmetic over deterministic graphs — no
timing — so the numbers are exactly reproducible and machine-independent.
`python3 scripts/streaming_model.py` prints the per-level table and
rewrites `BENCH_streaming.json` at the repo root when run with `--write`;
the Rust bench computes the same quantities natively and must agree
(EXPERIMENTS.md §Streaming documents the contract).  The bench's timing
fields (incremental vs scratch rebuild wall time) are intentionally NOT
part of the baseline: wall clock does not survive container changes.
"""

import json
import os
import sys

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
from packing_model import Rng, erdos_renyi, with_self_loops  # noqa: E402

TCB_R = 16
STEPS = 8
SEED = 0xBEEF
EDIT_LEVELS = [16, 64, 256, 1024]


def churn(adj, edits, rng):
    """Seeded mixed edit batch — lockstep with benches/streaming.rs."""
    n = len(adj)
    ins, rem = [], []
    for _ in range(edits):
        if rng.coin(0.5):
            u = rng.below(n)
            row = adj[u]
            if row:
                rem.append((u, row[rng.below(len(row))]))
                continue
        ins.append((rng.below(n), rng.below(n)))
    ins = [e for e in ins if e not in rem]
    return ins, rem


def apply_delta(adj, ins, rem):
    """GraphDelta::apply in set arithmetic: returns (patched, inserted,
    removed, dirty_rws) with no-op edits excluded, exactly like the Rust
    merge."""
    n = len(adj)
    ins_by = {}
    rem_by = {}
    for u, v in ins:
        ins_by.setdefault(u, set()).add(v)
    for u, v in rem:
        rem_by.setdefault(u, set()).add(v)
    inserted = removed = 0
    dirty_rows = []
    patched = []
    for u in range(n):
        s = set(adj[u])
        add = ins_by.get(u, set()) - s
        drop = rem_by.get(u, set()) & s
        inserted += len(add)
        removed += len(drop)
        ns = (s - drop) | add
        if ns != s:
            dirty_rows.append(u)
        patched.append(sorted(ns))
    dirty_rws = sorted({u // TCB_R for u in dirty_rows})
    return patched, inserted, removed, dirty_rws


def delta_wire_bytes(n_ins, n_rem):
    return (8 + 8 * n_ins) + (8 + 8 * n_rem)


def csr_wire_bytes(adj):
    n = len(adj)
    nnz = sum(len(r) for r in adj)
    return 8 + (8 + 4 * (n + 1)) + (8 + 4 * nnz)


def measure(base, edits):
    rng = Rng(SEED)
    adj = [list(r) for r in base]
    num_rw = -(-len(adj) // TCB_R)
    dirtied = inserted = removed = 0
    delta_bytes = naive_bytes = 0
    for _ in range(STEPS):
        ins, rem = churn(adj, edits, rng)
        delta_bytes += delta_wire_bytes(len(ins), len(rem))
        adj, i, r, dirty = apply_delta(adj, ins, rem)
        naive_bytes += csr_wire_bytes(adj)
        dirtied += len(dirty)
        inserted += i
        removed += r
    frac = dirtied / (num_rw * STEPS)
    return {
        "dirty_rw_fraction": round(frac, 6),
        "spliced_fraction": round(1.0 - frac, 6),
        "effective_inserts": inserted,
        "effective_removes": removed,
        "delta_bytes_ratio": round(delta_bytes / naive_bytes, 6),
    }


def main():
    write = "--write" in sys.argv
    base = with_self_loops(erdos_renyi(2048, 6.0, 7))
    levels = {}
    print(f"{'edits/step':>10} {'dirty_frac':>11} {'spliced':>9} "
          f"{'ins':>7} {'rem':>7} {'bytes_ratio':>12}")
    for edits in EDIT_LEVELS:
        row = measure(base, edits)
        print(f"{edits:>10} {row['dirty_rw_fraction']:>11.6f} "
              f"{row['spliced_fraction']:>9.6f} {row['effective_inserts']:>7} "
              f"{row['effective_removes']:>7} {row['delta_bytes_ratio']:>12.6f}")
        levels[str(edits)] = row
    if write:
        root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
        path = os.path.join(root, "BENCH_streaming.json")
        payload = {
            "bench": "streaming",
            "unit": "row-window fractions and wire-byte ratios "
                    "(structure-only, no wall clock)",
            "config": {
                "edit_levels": EDIT_LEVELS,
                "graph": "er_2048",
                "seed": SEED,
                "steps": STEPS,
            },
            "levels": levels,
        }
        with open(path, "w") as f:
            json.dump(payload, f, indent=1, sort_keys=True)
            f.write("\n")
        print(f"wrote {path}")


if __name__ == "__main__":
    main()
