#!/usr/bin/env bash
# Bench snapshotter (ROADMAP item 4, closed by ISSUE 9).
#
# Runs the timing bench suite with flake-resistant repeats and snapshots
# *machine-scaled ratios* — numbers normalized against a reference row
# measured in the same run (speedup-vs-serial, sharded-vs-unsharded,
# fingerprint-vs-inline, throughput-vs-best) — as BENCH_<bench>.json at
# the repo root.  Ratios survive container/CPU changes far better than
# wall clock, which is why raw milliseconds are never snapshotted.
#
#   scripts/bench_snapshot.sh                # all benches
#   scripts/bench_snapshot.sh shard multihead
#   REPEATS=5 scripts/bench_snapshot.sh      # median of 5 (default 3)
#
# Per bench, per key: REPEATS runs are collected, the min and max are
# discarded when enough samples exist (REPEATS >= 4), and the median of
# the rest is written.  `scripts/check_bench_regression.sh` compares a
# freshly rerun snapshot against the committed HEAD copy (±50% rel).
#
# The `streaming` bench is special-cased: its snapshot
# (BENCH_streaming.json) is *structural* — deterministic dirty/spliced
# window fractions, reproducible bit-for-bit by
# `scripts/streaming_model.py --write` — so one run suffices and no
# median is taken.  Without cargo, the streaming baseline is still
# regenerated from the Python model; the timing benches are skipped with
# a warning (exit 0: this script must be runnable in the offline
# verify environment).
set -euo pipefail

ROOT="$(cd "$(dirname "$0")/.." && pwd)"
REPEATS="${REPEATS:-3}"
BENCHES=("$@")
if [ ${#BENCHES[@]} -eq 0 ]; then
    BENCHES=(streaming host_pipeline coordinator_batching multihead shard net_loopback trace_overhead)
fi

have_cargo=1
command -v cargo >/dev/null 2>&1 || have_cargo=0

if ! command -v python3 >/dev/null 2>&1; then
    echo "WARN: python3 unavailable, bench snapshot skipped"
    exit 0
fi

tmp="$(mktemp -d)"
trap 'rm -rf "$tmp"' EXIT

run_bench() { # $1 = bench name, $2 = output file
    (cd "$ROOT/rust" && cargo bench --bench "$1" 2>/dev/null) >"$2"
}

for bench in "${BENCHES[@]}"; do
    if [ "$bench" = streaming ]; then
        # Structural snapshot: deterministic either way.
        if [ "$have_cargo" = 1 ]; then
            echo "== streaming (structural, 1 run via cargo)"
            run_bench streaming "$tmp/streaming.out" \
                || { echo "streaming bench FAILED"; exit 1; }
        else
            echo "== streaming (structural, via scripts/streaming_model.py)"
            python3 "$ROOT/scripts/streaming_model.py" --write >/dev/null
        fi
        echo "   wrote BENCH_streaming.json"
        continue
    fi
    if [ "$have_cargo" = 0 ]; then
        echo "WARN: cargo unavailable, timing bench '$bench' skipped"
        continue
    fi
    echo "== $bench ($REPEATS repeats)"
    for i in $(seq 1 "$REPEATS"); do
        run_bench "$bench" "$tmp/$bench.$i.out" \
            || { echo "$bench run $i FAILED"; exit 1; }
    done
    python3 - "$bench" "$ROOT" "$REPEATS" "$tmp" <<'EOF'
import json, re, statistics, sys

bench, root, repeats, tmp = sys.argv[1], sys.argv[2], int(sys.argv[3]), sys.argv[4]

def rows(path):
    """JSON rows a bench prints (one object per config)."""
    out = []
    for line in open(path):
        line = line.strip()
        if line.startswith('{"bench"'):
            try:
                out.append(json.loads(line))
            except ValueError:
                pass
    return out

def net_rows(path):
    """net_loopback prints a table: '  n=NNN  <inline>us  <fp>us  <bytes>'."""
    out = []
    pat = re.compile(r"n=(\d+)\s+([\d.]+)us\s+([\d.]+)us\s+(\d+)")
    for line in open(path):
        m = pat.search(line)
        if m:
            n, inline_us, fp_us = int(m.group(1)), float(m.group(2)), float(m.group(3))
            out.append({"n": n, "inline_us": inline_us, "fp_us": fp_us})
    return out

def extract(path):
    """-> {key: machine-scaled ratio} for one run of `bench`."""
    got = {}
    if bench == "host_pipeline":
        for r in rows(path):
            got[f"t{r['threads']}_p{r['pipeline_depth']}"] = r["speedup_e2e"]
    elif bench == "multihead":
        for r in rows(path):
            got[f"{r['dataset']}_h{r['heads']}_d{r['d']}"] = r["speedup"]
    elif bench == "shard":
        for r in rows(path):
            if r.get("mode") == "sharded":
                got[f"{r['generator']}_s{r['shards']}"] = r["vs_unsharded"]
    elif bench == "coordinator_batching":
        rs = rows(path)
        best = max((r["throughput_rps"] for r in rs), default=0.0)
        for r in rs:
            key = f"d{r['delay_us']}_r{r['max_requests']}"
            got[key] = r["throughput_rps"] / best if best > 0 else 0.0
    elif bench == "net_loopback":
        for r in net_rows(path):
            if r["inline_us"] > 0:
                got[f"n{r['n']}"] = r["fp_us"] / r["inline_us"]
    elif bench == "trace_overhead":
        for r in rows(path):
            got["armed_over_disarmed"] = r["armed_over_disarmed"]
            got["recording_over_disarmed"] = r["recording_over_disarmed"]
    return got

samples = {}
for i in range(1, repeats + 1):
    for key, v in extract(f"{tmp}/{bench}.{i}.out").items():
        samples.setdefault(key, []).append(v)
if not samples:
    print(f"{bench}: no parsable rows — snapshot not written")
    sys.exit(1)

keys = {}
for key, vals in sorted(samples.items()):
    vals = sorted(vals)
    if len(vals) >= 4:  # discard-outlier: drop the extremes, median the rest
        vals = vals[1:-1]
    keys[key] = round(statistics.median(vals), 4)

payload = {
    "bench": bench,
    "repeats": repeats,
    "unit": "machine-scaled ratios (median of repeats, extremes discarded "
            "at >=4; normalized within-run, no raw wall clock)",
    "keys": keys,
}
path = f"{root}/BENCH_{bench}.json"
with open(path, "w") as f:
    json.dump(payload, f, indent=1, sort_keys=True)
    f.write("\n")
print(f"   wrote BENCH_{bench}.json ({len(keys)} keys)")
EOF
done

echo "bench snapshot done"
