#!/usr/bin/env bash
# Tier-1 verification for the Fused3S reproduction (offline-safe: the
# vendored anyhow/xla stubs make every step run with no network and no
# system libxla).  Usage: scripts/verify.sh
#
# cargo fmt / clippy run when their components are installed; style drift
# is reported but only build + test failures are fatal (tier-1 contract).
set -euo pipefail

cd "$(dirname "$0")/../rust"
REPO_ROOT="$(cd .. && pwd)"

# Docs-link check: every markdown file referenced from another markdown
# file or from source rustdoc must exist, so a dangling architecture doc
# (the DESIGN.md that ISSUEs 0-3 cited without writing) can never ship
# again.  References resolve relative to the repo root; paths under /opt
# point at baked-in container material and are skipped.
echo "== docs-link check"
docs_missing=0
refs=$(grep -rhoE '[A-Za-z0-9_][A-Za-z0-9_./-]*[.]md' \
        --include='*.md' --include='*.rs' --include='*.sh' --include='*.py' \
        "$REPO_ROOT" \
        --exclude-dir=target --exclude-dir=vendor --exclude-dir=.git \
        | sed 's#^\./##' | sort -u)
for ref in $refs; do
    case "$ref" in
        opt/*) continue ;; # /opt/... container paths, not repo docs
    esac
    if [ ! -f "$REPO_ROOT/$ref" ]; then
        echo "MISSING doc reference: $ref"
        docs_missing=1
    fi
done
if [ "$docs_missing" -ne 0 ]; then
    echo "docs-link check FAILED"
    exit 1
fi
echo "docs-link check OK"

# ISSUE-4 perf-memory gate: the bench suite snapshots normalized ratio
# baselines at the repo root (BENCH_*.json — structure-only cell ratios
# for packing, serial-reference time ratios for planner).  A missing
# committed baseline means regressions ship invisibly, so its absence is
# fatal.  BENCH_planner.json is written by `cargo bench --bench planner`
# and only checked when present (timing benches don't run under tier-1).
echo "== bench baseline presence (BENCH_*.json)"
if [ ! -f "$REPO_ROOT/BENCH_packing.json" ]; then
    echo "MISSING baseline: BENCH_packing.json (run 'cargo bench --bench" \
         "packing' or 'python3 scripts/packing_model.py --write')"
    exit 1
fi
grep -q '"padded_cell_ratio"' "$REPO_ROOT/BENCH_packing.json" || {
    echo "BENCH_packing.json lacks padded_cell_ratio entries"; exit 1; }
# ISSUE-9 adds the structural streaming baseline (dirty/spliced window
# fractions under churn) — deterministic like packing, so absence is fatal.
if [ ! -f "$REPO_ROOT/BENCH_streaming.json" ]; then
    echo "MISSING baseline: BENCH_streaming.json (run 'cargo bench --bench" \
         "streaming' or 'python3 scripts/streaming_model.py --write')"
    exit 1
fi
grep -q '"dirty_rw_fraction"' "$REPO_ROOT/BENCH_streaming.json" || {
    echo "BENCH_streaming.json lacks dirty_rw_fraction entries"; exit 1; }
echo "bench baseline presence OK"

# ISSUE-8 regression gate: a *present but stale* baseline is as dangerous
# as a missing one.  Regenerate the deterministic packing baseline from
# the reference model and diff it against the committed copy (±0.02 abs
# on cell ratios); compare a freshly rerun planner baseline against HEAD
# (±50% rel on time ratios).  Restores the committed files afterwards.
echo "== bench regression check (scripts/check_bench_regression.sh)"
"$REPO_ROOT/scripts/check_bench_regression.sh"

# ISSUE-6 hygiene gate: the coordinator and executor hot paths must not
# grow new bare `unwrap()`/`expect()` calls — lock poisoning and fallible
# seams go through util::sync::lock_unpoisoned or structured AttnError.
# A site that is genuinely unreachable stays allowed when the line (or
# the comment block directly above it) says why with the word "invariant".
# Test modules (everything after `#[cfg(test)]`) are exempt.  ISSUE 7
# extends the file set with the geometry router and the hybrid driver —
# new dispatch-path modules inherit the same hygiene bar; ISSUE 8 adds
# the network serving layer (src/net/), which parses hostile input and
# so must never unwrap its way into a session panic; ISSUE 9 adds the
# streaming delta/incremental-rebuild modules, which sit on the
# update_graph hot path and validate caller-supplied edit batches; ISSUE
# 10 adds the tracing ring (src/trace/), whose hooks run on every hot
# path and must degrade to a no-op, never a panic.
echo "== unwrap/expect lint (src/coordinator, src/exec, src/bsb/geometry.rs, src/kernels/hybrid.rs, src/net, src/graph/delta.rs, src/bsb/incremental.rs, src/trace)"
awk '
    FNR == 1 { intest = 0; inv = 0 }
    /#\[cfg\(test\)\]/ { intest = 1 }
    {
        if ($0 ~ /^[[:space:]]*\/\//) {
            if ($0 ~ /invariant/) inv = 1
            next
        }
        if (!intest && $0 ~ /\.(unwrap|expect)\(/ \
            && $0 !~ /unwrap_or/ && $0 !~ /invariant/ && !inv) {
            printf "%s:%d: bare unwrap/expect outside tests: %s\n", \
                FILENAME, FNR, $0
            bad = 1
        }
        inv = 0
    }
    END { exit bad }
' src/coordinator/*.rs src/exec/*.rs src/bsb/geometry.rs \
    src/kernels/hybrid.rs src/net/*.rs src/graph/delta.rs \
    src/bsb/incremental.rs src/trace/*.rs
echo "unwrap/expect lint OK"

if cargo fmt --version >/dev/null 2>&1; then
    echo "== cargo fmt --check"
    cargo fmt --check || echo "WARN: rustfmt drift (non-fatal)"
else
    echo "== cargo fmt --check (skipped: rustfmt not installed)"
fi

if cargo clippy --version >/dev/null 2>&1; then
    echo "== cargo clippy -- -D warnings"
    cargo clippy -- -D warnings || echo "WARN: clippy findings (non-fatal)"
else
    echo "== cargo clippy (skipped: clippy not installed)"
fi

echo "== cargo build --release"
cargo build --release

echo "== cargo test -q"
cargo test -q

# The ISSUE-2/ISSUE-3 differential harnesses, run explicitly so a filtered
# or partially-cached test invocation can never silently skip them.  The
# multihead suite is the acceptance gate for the plan-based API: one
# AttentionBatch call must bit-match the per-head loop on every backend.
echo "== cargo test -q --test batching_equivalence --test backward_gradcheck --test multihead_equivalence"
cargo test -q --test batching_equivalence --test backward_gradcheck \
    --test multihead_equivalence

# The ISSUE-4 planner suite: synthetic extremes pick the expected backend,
# Backend::Auto bit-matches the forced-backend run (standalone and through
# the coordinator), and the cost-model calibration persists.
echo "== cargo test -q --test planner_selection"
cargo test -q --test planner_selection

# The ISSUE-7 packing suite: hybrid geometry routing (wide/narrow/dense
# per row window) must bit-match the 16-row all-wide reference and the
# fused driver — across generators, heads {1,4}, d != dv, serial and
# parallel engines, and the HostEmulation coordinator — and Backend::Auto
# must pick hybrid only when the cost model prices it cheaper.
echo "== cargo test -q --test packing_equivalence"
cargo test -q --test packing_equivalence

# The ISSUE-5 sharding suite: partition-parallel execution must bit-match
# the unsharded plan (every shardable backend, shard counts, strategies,
# heads, mega-hub chunked RWs) and the coordinator must serve graphs past
# max_plan_nodes through the sharded path.
echo "== cargo test -q --test shard_equivalence"
cargo test -q --test shard_equivalence

# Coordinator suite serialized: the stress tests spawn their own submitter
# threads and assert timing-sensitive coalescing/backpressure behaviour, so
# they must not interleave with each other.
echo "== coordinator suite (--test-threads=1)"
cargo test -q --test coordinator_stress --test coordinator_integration \
    -- --test-threads=1

# The ISSUE-6 chaos suite: seeded deterministic fault injection (seeds
# {1,2,3} × rates {0%,5%,25%} pinned in the test) against the fault-free
# differential baseline — exactly one response per request, bit-match on
# the requested backend, structured errors, clean shutdown, reconciled
# fault counters.  Serialized: the fault hook is process-global.
echo "== chaos suite (--test-threads=1)"
cargo test -q --test chaos -- --test-threads=1

# The ISSUE-8 serving suite: responses served over loopback TCP must
# bit-match the in-process submit path (per-backend and Backend::Auto,
# fingerprint handshake, drain-on-shutdown), and hostile frames —
# truncations, oversize prefixes, bad magic/version/token, invalid CSR,
# mid-frame disconnects — must end in a structured error or clean close,
# never a panic or leaked quota slot.  Serialized: the hardening suite
# arms the process-global fault hook.
echo "== net suite (--test-threads=1)"
cargo test -q --test net_loopback --test net_hardening -- --test-threads=1

# The ISSUE-9 streaming suite: every delta-patched graph and incrementally
# rebuilt BSB must bit-match the from-scratch build (generators × edit
# mixes × heads × engines, plus a 1-50 batch cumulative fuzz), and
# `Coordinator::update_graph` must swap plan versions atomically — zero
# stale-plan cache hits after a swap, old version evicted only after the
# new plans land.  Serialized: the cache-swap tests count process-global
# hit/miss metrics.
echo "== streaming suite (--test-threads=1)"
cargo test -q --test streaming_equivalence -- --test-threads=1

# The ISSUE-10 tracing suite: arming the process-global tracer at
# sample_rate 1.0 must be bit-invisible to every output (standalone plans,
# coordinator, sharded path); the captured ring must show balanced span
# nesting in claim order and a Chrome-loadable export; and the metrics
# report matrix pins report()/to_json() section behaviour.  Serialized:
# trace::install is latest-wins process-global.
echo "== tracing suite (--test-threads=1)"
cargo test -q --test tracing_differential --test metrics_report \
    -- --test-threads=1

# The redesigned public API must stay documented: rustdoc warnings
# (broken intra-doc links, missing code-block languages, ...) are errors.
echo "== cargo doc --no-deps (warnings denied)"
RUSTDOCFLAGS="-D warnings" cargo doc --no-deps --quiet

echo "verify: OK"
echo "(perf sweeps: 'cargo bench --bench host_pipeline' for the host engine,"
echo " 'cargo bench --bench coordinator_batching' for the dynamic-batching"
echo " delay × nodes sweep, 'cargo bench --bench multihead' for the"
echo " head-batching sweep, 'cargo bench --bench planner' for the"
echo " auto-vs-fixed backend sweep, 'cargo bench --bench packing' for the"
echo " hybrid-geometry padded-cell sweep, 'cargo bench --bench shard' for"
echo " the sharded-vs-unsharded sweep, 'cargo bench --bench fault_overhead'"
echo " for the disabled-injection hot-path cost, 'cargo bench --bench"
echo " trace_overhead' for the disarmed/armed tracing seam cost, 'cargo"
echo " bench --bench streaming' for the incremental-vs-scratch rebuild"
echo " sweep, and 'scripts/bench_snapshot.sh' to snapshot the whole suite"
echo " as machine-scaled BENCH_*.json ratios; see EXPERIMENTS.md"
echo " §Perf/§Batching/§Multi-head/§Planner/§Sharding/§Faults/§Packing/§Streaming/§Tracing)"
