#!/usr/bin/env bash
# Bench-baseline regression check (ISSUE 8, satellite 1).
#
# The repo pins normalized bench baselines at the root (BENCH_*.json).
# `scripts/verify.sh` already fails when a baseline is *missing*; this
# script goes further and fails when a freshly *regenerated* baseline
# drifts outside a per-bench tolerance band:
#
#   * BENCH_packing.json  — regenerated via the deterministic reference
#     model (scripts/packing_model.py --write): integer plan arithmetic,
#     so the committed and fresh cell ratios must agree to ±0.02 abs.
#     Any drift means the packing arithmetic (or its PRNG) changed.
#   * BENCH_planner.json  — timing ratios, machine-scaled but still
#     noisy; only checked when the file exists AND differs from the
#     committed HEAD copy (i.e. `cargo bench --bench planner` was just
#     rerun).  Each backend's time-ratio may move ±50% relative before
#     we call it a regression.
#
# The committed packing baseline is restored after regeneration, so the
# check never dirties the work tree.  Exit 0 with a warning when python3
# is unavailable (the comparison needs it).
set -euo pipefail

ROOT="$(cd "$(dirname "$0")/.." && pwd)"

if ! command -v python3 >/dev/null 2>&1; then
    echo "WARN: python3 unavailable, bench regression check skipped"
    exit 0
fi

tmp="$(mktemp -d)"
trap 'rm -rf "$tmp"' EXIT

# --- packing: deterministic, tight band ---------------------------------
PACK="$ROOT/BENCH_packing.json"
if [ -f "$PACK" ]; then
    cp "$PACK" "$tmp/packing_committed.json"
    python3 "$ROOT/scripts/packing_model.py" --write >/dev/null
    mv "$PACK" "$tmp/packing_fresh.json"
    # Restore the committed baseline *before* comparing so a failed
    # comparison still leaves the tree clean.
    cp "$tmp/packing_committed.json" "$PACK"
    python3 - "$tmp/packing_committed.json" "$tmp/packing_fresh.json" <<'EOF'
import json, sys

TOL = 0.02  # absolute, on normalized cell ratios
committed = json.load(open(sys.argv[1]))
fresh = json.load(open(sys.argv[2]))
cg, fg = committed["graphs"], fresh["graphs"]
bad = 0
for name in sorted(set(cg) | set(fg)):
    if name not in cg or name not in fg:
        print(f"packing: graph set changed: {name!r} present on one side only")
        bad = 1
        continue
    for key in ("padded_cell_ratio", "dispatched_cell_ratio"):
        a, b = float(cg[name][key]), float(fg[name][key])
        if abs(a - b) > TOL:
            print(
                f"packing REGRESSION: {name}.{key}: committed {a:.6f} "
                f"vs fresh {b:.6f} (tol +-{TOL})"
            )
            bad = 1
sys.exit(bad)
EOF
    echo "packing baseline OK (fresh model within +-0.02 of committed)"
else
    echo "WARN: BENCH_packing.json absent, packing regression check skipped"
fi

# --- streaming: deterministic, tight band -------------------------------
STREAM="$ROOT/BENCH_streaming.json"
if [ -f "$STREAM" ]; then
    cp "$STREAM" "$tmp/streaming_committed.json"
    python3 "$ROOT/scripts/streaming_model.py" --write >/dev/null
    mv "$STREAM" "$tmp/streaming_fresh.json"
    cp "$tmp/streaming_committed.json" "$STREAM"
    python3 - "$tmp/streaming_committed.json" "$tmp/streaming_fresh.json" <<'EOF'
import json, sys

TOL = 0.02  # absolute, on window fractions / byte ratios
committed = json.load(open(sys.argv[1]))
fresh = json.load(open(sys.argv[2]))
cl, fl = committed["levels"], fresh["levels"]
bad = 0
for level in sorted(set(cl) | set(fl)):
    if level not in cl or level not in fl:
        print(f"streaming: edit-level set changed: {level!r} on one side only")
        bad = 1
        continue
    for key in ("dirty_rw_fraction", "spliced_fraction", "delta_bytes_ratio"):
        a, b = float(cl[level][key]), float(fl[level][key])
        if abs(a - b) > TOL:
            print(
                f"streaming REGRESSION: {level}.{key}: committed {a:.6f} "
                f"vs fresh {b:.6f} (tol +-{TOL})"
            )
            bad = 1
    for key in ("effective_inserts", "effective_removes"):
        a, b = int(cl[level][key]), int(fl[level][key])
        if a != b:
            print(
                f"streaming REGRESSION: {level}.{key}: committed {a} "
                f"vs fresh {b} (integer counts must match exactly)"
            )
            bad = 1
sys.exit(bad)
EOF
    echo "streaming baseline OK (fresh model within +-0.02 of committed)"
else
    echo "WARN: BENCH_streaming.json absent, streaming regression check skipped"
fi

# --- planner: timing ratios, wide band, only when freshly rerun ---------
PLAN="$ROOT/BENCH_planner.json"
if [ -f "$PLAN" ] \
    && git -C "$ROOT" ls-files --error-unmatch BENCH_planner.json \
        >/dev/null 2>&1; then
    if git -C "$ROOT" diff --quiet -- BENCH_planner.json; then
        echo "planner baseline unchanged vs HEAD (bench not rerun) — skipped"
    else
        git -C "$ROOT" show HEAD:BENCH_planner.json \
            >"$tmp/planner_head.json"
        python3 - "$tmp/planner_head.json" "$PLAN" <<'EOF'
import json, sys

TOL = 0.50  # relative, on time ratios (timing benches are noisy)
head = json.load(open(sys.argv[1]))
cur = json.load(open(sys.argv[2]))
hg, cg = head["generators"], cur["generators"]
bad = 0
for gen in sorted(set(hg) & set(cg)):
    for key, v in hg[gen].items():
        if key == "resolved" or key not in cg[gen]:
            continue
        a, b = float(v), float(cg[gen][key])
        if a > 0 and abs(b - a) / a > TOL:
            print(
                f"planner REGRESSION: {gen}.{key}: HEAD ratio {a:.4f} "
                f"vs fresh {b:.4f} (tol +-{TOL*100:.0f}% rel)"
            )
            bad = 1
sys.exit(bad)
EOF
        echo "planner baseline OK (fresh ratios within +-50% of HEAD)"
    fi
else
    echo "planner baseline absent or untracked (timing bench) — skipped"
fi

# --- snapshot suite: timing ratios, wide band, only when freshly rerun --
# BENCH_<bench>.json files written by scripts/bench_snapshot.sh share one
# schema ({"keys": {key: ratio}}); compare each against its HEAD copy the
# same way the planner baseline is handled.
for bench in host_pipeline coordinator_batching multihead shard net_loopback \
    trace_overhead; do
    SNAP="$ROOT/BENCH_$bench.json"
    if [ -f "$SNAP" ] \
        && git -C "$ROOT" ls-files --error-unmatch "BENCH_$bench.json" \
            >/dev/null 2>&1; then
        if git -C "$ROOT" diff --quiet -- "BENCH_$bench.json"; then
            echo "$bench snapshot unchanged vs HEAD (bench not rerun) — skipped"
        else
            git -C "$ROOT" show "HEAD:BENCH_$bench.json" \
                >"$tmp/${bench}_head.json"
            python3 - "$tmp/${bench}_head.json" "$SNAP" "$bench" <<'EOF'
import json, sys

TOL = 0.50  # relative, on machine-scaled ratios (timing benches are noisy)
head = json.load(open(sys.argv[1]))
cur = json.load(open(sys.argv[2]))
bench = sys.argv[3]
hk, ck = head.get("keys", {}), cur.get("keys", {})
bad = 0
for key in sorted(set(hk) & set(ck)):
    a, b = float(hk[key]), float(ck[key])
    if a > 0 and abs(b - a) / a > TOL:
        print(
            f"{bench} REGRESSION: {key}: HEAD ratio {a:.4f} "
            f"vs fresh {b:.4f} (tol +-{TOL*100:.0f}% rel)"
        )
        bad = 1
sys.exit(bad)
EOF
            echo "$bench snapshot OK (fresh ratios within +-50% of HEAD)"
        fi
    else
        echo "$bench snapshot absent or untracked (timing bench) — skipped"
    fi
done

echo "bench regression check OK"
