#!/usr/bin/env bash
# Bench-baseline regression check (ISSUE 8, satellite 1).
#
# The repo pins normalized bench baselines at the root (BENCH_*.json).
# `scripts/verify.sh` already fails when a baseline is *missing*; this
# script goes further and fails when a freshly *regenerated* baseline
# drifts outside a per-bench tolerance band:
#
#   * BENCH_packing.json  — regenerated via the deterministic reference
#     model (scripts/packing_model.py --write): integer plan arithmetic,
#     so the committed and fresh cell ratios must agree to ±0.02 abs.
#     Any drift means the packing arithmetic (or its PRNG) changed.
#   * BENCH_planner.json  — timing ratios, machine-scaled but still
#     noisy; only checked when the file exists AND differs from the
#     committed HEAD copy (i.e. `cargo bench --bench planner` was just
#     rerun).  Each backend's time-ratio may move ±50% relative before
#     we call it a regression.
#
# The committed packing baseline is restored after regeneration, so the
# check never dirties the work tree.  Exit 0 with a warning when python3
# is unavailable (the comparison needs it).
set -euo pipefail

ROOT="$(cd "$(dirname "$0")/.." && pwd)"

if ! command -v python3 >/dev/null 2>&1; then
    echo "WARN: python3 unavailable, bench regression check skipped"
    exit 0
fi

tmp="$(mktemp -d)"
trap 'rm -rf "$tmp"' EXIT

# --- packing: deterministic, tight band ---------------------------------
PACK="$ROOT/BENCH_packing.json"
if [ -f "$PACK" ]; then
    cp "$PACK" "$tmp/packing_committed.json"
    python3 "$ROOT/scripts/packing_model.py" --write >/dev/null
    mv "$PACK" "$tmp/packing_fresh.json"
    # Restore the committed baseline *before* comparing so a failed
    # comparison still leaves the tree clean.
    cp "$tmp/packing_committed.json" "$PACK"
    python3 - "$tmp/packing_committed.json" "$tmp/packing_fresh.json" <<'EOF'
import json, sys

TOL = 0.02  # absolute, on normalized cell ratios
committed = json.load(open(sys.argv[1]))
fresh = json.load(open(sys.argv[2]))
cg, fg = committed["graphs"], fresh["graphs"]
bad = 0
for name in sorted(set(cg) | set(fg)):
    if name not in cg or name not in fg:
        print(f"packing: graph set changed: {name!r} present on one side only")
        bad = 1
        continue
    for key in ("padded_cell_ratio", "dispatched_cell_ratio"):
        a, b = float(cg[name][key]), float(fg[name][key])
        if abs(a - b) > TOL:
            print(
                f"packing REGRESSION: {name}.{key}: committed {a:.6f} "
                f"vs fresh {b:.6f} (tol +-{TOL})"
            )
            bad = 1
sys.exit(bad)
EOF
    echo "packing baseline OK (fresh model within +-0.02 of committed)"
else
    echo "WARN: BENCH_packing.json absent, packing regression check skipped"
fi

# --- planner: timing ratios, wide band, only when freshly rerun ---------
PLAN="$ROOT/BENCH_planner.json"
if [ -f "$PLAN" ] \
    && git -C "$ROOT" ls-files --error-unmatch BENCH_planner.json \
        >/dev/null 2>&1; then
    if git -C "$ROOT" diff --quiet -- BENCH_planner.json; then
        echo "planner baseline unchanged vs HEAD (bench not rerun) — skipped"
    else
        git -C "$ROOT" show HEAD:BENCH_planner.json \
            >"$tmp/planner_head.json"
        python3 - "$tmp/planner_head.json" "$PLAN" <<'EOF'
import json, sys

TOL = 0.50  # relative, on time ratios (timing benches are noisy)
head = json.load(open(sys.argv[1]))
cur = json.load(open(sys.argv[2]))
hg, cg = head["generators"], cur["generators"]
bad = 0
for gen in sorted(set(hg) & set(cg)):
    for key, v in hg[gen].items():
        if key == "resolved" or key not in cg[gen]:
            continue
        a, b = float(v), float(cg[gen][key])
        if a > 0 and abs(b - a) / a > TOL:
            print(
                f"planner REGRESSION: {gen}.{key}: HEAD ratio {a:.4f} "
                f"vs fresh {b:.4f} (tol +-{TOL*100:.0f}% rel)"
            )
            bad = 1
sys.exit(bad)
EOF
        echo "planner baseline OK (fresh ratios within +-50% of HEAD)"
    fi
else
    echo "planner baseline absent or untracked (timing bench) — skipped"
fi

echo "bench regression check OK"
