//! End-to-end driver (EXPERIMENTS.md §E2E): Graph Transformer inference on
//! a realistic workload through the full three-layer stack — Rust
//! coordinator → AOT dense-tile executables → fused Pallas 3S kernel —
//! reporting per-stage latency and the attention-time fraction (the
//! paper's Fig. 8 measurement), plus a cross-backend agreement check.
//!
//! ```sh
//! make artifacts && cargo run --release --example graph_transformer -- \
//!     --dataset pubmed-sim --d 64 --blocks 10
//! ```

use fused3s::graph::datasets;
use fused3s::kernels::{reference, Backend};
use fused3s::model::weights::random_features;
use fused3s::model::{GraphTransformer, GtConfig};
use fused3s::runtime::Runtime;
use fused3s::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env()?;
    let name = args.get_or("dataset", "cora-sim");
    let d = args.usize_or("d", 64)?;
    let blocks = args.usize_or("blocks", 10)?;

    let ds = datasets::by_name(&name)?;
    let rt = Runtime::from_default_artifacts()?;
    println!(
        "Graph Transformer: {} (n={}, nnz={}), d={d}, {blocks} blocks, \
         {} heads/layer",
        ds.name,
        ds.graph.n,
        ds.graph.nnz(),
        d / fused3s::model::D_HEAD
    );

    let h = random_features(1, ds.graph.n, d);
    let mut outputs: Vec<(Backend, Vec<f32>)> = Vec::new();
    for backend in [Backend::Fused3S, Backend::UnfusedStable] {
        let cfg = GtConfig { d, n_blocks: blocks, backend, seed: 0x5EED };
        let model = GraphTransformer::prepare(&rt, &ds.graph, cfg)?;
        let (_, warm) = model.infer(&rt, &h)?; // compile warmup
        let (out, t) = model.infer(&rt, &h)?;
        println!(
            "  {:<16} warm {:>8.1} ms | steady {:>8.1} ms  \
             (attention {:>6.1} ms = {:>4.1}%, dense {:>6.1} ms)",
            backend.name(),
            warm.total_s * 1e3,
            t.total_s * 1e3,
            t.attention_s * 1e3,
            t.attention_fraction() * 100.0,
            t.dense_s * 1e3,
        );
        outputs.push((backend, out));
    }
    // The kernels must agree on the model output (bf16-level drift).
    let err = reference::max_abs_diff(&outputs[0].1, &outputs[1].1);
    println!("cross-backend max |diff|: {err:.3}");
    anyhow::ensure!(err < 0.5, "backends disagree");
    println!("OK — all layers composed through the AOT artifact path");
    Ok(())
}
