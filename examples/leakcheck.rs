//! Memory/perf check used during the §Perf pass: repeated fused runs must
//! show flat RSS and stable latency (guards against the Literal-execute
//! leak in xla_extension 0.5.1 regressing back in — see runtime/client.rs).

use fused3s::exec::Engine;
use fused3s::graph::datasets;
use fused3s::kernels::{AttentionBatch, AttentionProblem, Backend, ExecCtx, Plan};
use fused3s::runtime::Runtime;
use fused3s::util::prng::Rng;

fn rss_mb() -> f64 {
    let s = std::fs::read_to_string("/proc/self/statm").unwrap();
    let pages: f64 = s.split_whitespace().nth(1).unwrap().parse().unwrap();
    pages * 4096.0 / 1e6
}

fn main() {
    let rt = Runtime::from_default_artifacts().unwrap();
    let ds = datasets::by_name("github-sim").unwrap();
    let n = ds.graph.n;
    let d = 64;
    let mut rng = Rng::new(1);
    let q = rng.normal_vec(n * d, 1.0);
    let k = rng.normal_vec(n * d, 1.0);
    let v = rng.normal_vec(n * d, 1.0);
    let x = AttentionProblem::new(n, d, &q, &k, &v, 0.125);
    let batch = AttentionBatch::single(&x);
    let engine = Engine::serial();
    let plan = Plan::new(rt.manifest(), &ds.graph, Backend::Fused3S, &engine).unwrap();
    let mut rss_after_warm = 0.0;
    for i in 0..12 {
        let t0 = std::time::Instant::now();
        let _ = plan.execute(&mut ExecCtx::pjrt(&rt, &engine), &batch).unwrap();
        let rss = rss_mb();
        if i == 1 {
            rss_after_warm = rss;
        }
        println!(
            "iter {i}: {:.1} ms, rss {:.0} MB",
            t0.elapsed().as_secs_f64() * 1e3,
            rss
        );
    }
    let growth = rss_mb() - rss_after_warm;
    println!("rss growth after warmup: {growth:.0} MB");
    assert!(growth < 50.0, "memory leak regression: {growth:.0} MB");
}
