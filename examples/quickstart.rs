//! Quickstart: the smallest end-to-end use of the Fused3S stack.
//!
//! Builds a small graph, runs fused sparse attention through the AOT
//! kernel, and verifies against the host reference.
//!
//! ```sh
//! make artifacts && cargo run --release --example quickstart
//! ```

use fused3s::exec::Engine;
use fused3s::graph::generators;
use fused3s::kernels::{
    reference, AttentionBatch, AttentionProblem, Backend, Driver, ExecCtx, Plan,
};
use fused3s::runtime::Runtime;
use fused3s::util::prng::Rng;

fn main() -> anyhow::Result<()> {
    // 1. The runtime loads + lazily compiles the AOT artifact suite.
    let rt = Runtime::from_default_artifacts()?;
    println!("PJRT platform: {}", rt.platform());

    // 2. A graph = a sparse attention pattern (adjacency matrix A).
    let g = generators::barabasi_albert(1000, 5, 42).with_self_loops();
    println!("graph: n={} nnz={}", g.n, g.nnz());

    // 2b. The adaptive planner's opinion (what `Backend::Auto` would do):
    // profile the sparsity, price every backend, pick the cheapest.
    let decision = fused3s::planner::resolve(&g);
    println!(
        "planner: Backend::Auto would route this graph to '{}'{}",
        decision.backend.name(),
        if decision.chunked { " (chunked hub path)" } else { "" }
    );

    // 3. Plan once: BSB build + row-window reordering + bucket plan.
    let engine = Engine::serial();
    let plan = Plan::new(rt.manifest(), &g, Backend::Fused3S, &engine)?;
    if let Driver::Fused(f) = plan.driver() {
        println!(
            "BSB: {} row windows, {} TCBs, {} kernel dispatches planned \
             (padding {:.1}%)",
            f.bsb.num_rw,
            f.bsb.total_tcbs(),
            f.plan.stats.n_calls,
            f.plan.stats.padding_ratio() * 100.0
        );
    }

    // 4. Run O = softmax(QK^T/sqrt(d) ⊙ A) V.
    let d = 64;
    let mut rng = Rng::new(7);
    let q = rng.normal_vec(g.n * d, 1.0);
    let k = rng.normal_vec(g.n * d, 1.0);
    let v = rng.normal_vec(g.n * d, 1.0);
    let x = AttentionProblem::new(g.n, d, &q, &k, &v, 1.0 / (d as f32).sqrt());
    let batch = AttentionBatch::single(&x);
    let t0 = std::time::Instant::now();
    let out = plan.execute(&mut ExecCtx::pjrt(&rt, &engine), &batch)?;
    println!("fused 3S: {:.2} ms (first call compiles executables)", t0.elapsed().as_secs_f64() * 1e3);
    let t0 = std::time::Instant::now();
    let out2 = plan.execute(&mut ExecCtx::pjrt(&rt, &engine), &batch)?;
    println!("fused 3S: {:.2} ms (warm)", t0.elapsed().as_secs_f64() * 1e3);
    assert_eq!(out.len(), out2.len());

    // 5. Verify against the exact host reference.
    let want = reference::dense_attention_host(&g, &x);
    let err = reference::max_abs_diff(&out, &want);
    println!("max |err| vs exact reference: {err:.2e} (bf16 kernel)");
    assert!(err < 0.15);
    println!("OK");
    Ok(())
}
