//! Format tour: build the BSB format step by step on a small graph and
//! print every structure (row windows, compaction, TCBs, bitmaps) next to
//! the Table-3 footprint comparison — a readable companion to paper §3.1 /
//! Figure 1.
//!
//! ```sh
//! cargo run --release --example format_tour
//! ```

use fused3s::bsb::{self, bitmap, footprint, stats};
use fused3s::graph::CsrGraph;

fn main() -> anyhow::Result<()> {
    // The Figure-1-style toy matrix: one row window, scattered columns.
    let edges: &[(u32, u32)] = &[
        (0, 3), (0, 17), (1, 17), (1, 40), (2, 3), (3, 99), (4, 100),
        (5, 101), (6, 40), (7, 41), (9, 3), (12, 102), (15, 3), (15, 101),
    ];
    let g = CsrGraph::from_edges(128, edges)?;
    let b = bsb::build(&g);

    println!("matrix: {}x{}, {} nonzeros", g.n, g.n, g.nnz());
    println!("row windows (r=16): {}", b.num_rw);
    for rw in 0..b.num_rw {
        let t = b.rw_tcbs(rw);
        if t == 0 {
            continue;
        }
        println!("\nrow window {rw}: {t} TCB(s) after column compaction");
        for j in 0..t {
            let cols = b.tcb_cols(rw, j);
            let bm = b.tcb_bitmap(rw, j);
            println!(
                "  TCB {j}: columns {:?}  nnz={}",
                cols.iter()
                    .map(|&c| if c == u32::MAX { "-".into() } else { c.to_string() })
                    .collect::<Vec<_>>(),
                bitmap::popcount(bm),
            );
            for r in 0..16 {
                let row: String = (0..8)
                    .map(|c| if bitmap::get(bm, r, c) { '#' } else { '.' })
                    .collect();
                if row.contains('#') {
                    println!("    row {r:>2}: {row}");
                }
            }
        }
    }

    let st = stats::compaction_stats(&b);
    println!(
        "\ncompaction stats: TCB/RW avg {:.2} (cv {:.2}), nnz/TCB avg {:.2}",
        st.tcb_per_rw_avg, st.tcb_per_rw_cv, st.nnz_per_tcb_avg
    );

    println!("\nTable-3 footprints for a real graph (pubmed-sim):");
    let d = fused3s::graph::datasets::by_name("pubmed-sim")?;
    let inputs = footprint::measure(&d.graph);
    for (name, bits) in footprint::table3_rows(&inputs) {
        println!("  {:<8} {:>10.2} KiB", name, bits as f64 / 8.0 / 1024.0);
    }
    Ok(())
}
