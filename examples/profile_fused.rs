//! §Perf probe: wall-clock breakdown of one fused 3S run — gather vs PJRT
//! execution vs scatter — per bucket, on a chosen dataset.

use fused3s::exec::Engine;
use fused3s::graph::datasets;
use fused3s::kernels::gather::{self, CallBuffers};
use fused3s::kernels::fused::{FusedDriver, FusedOpts};
use fused3s::kernels::{AttentionBatch, AttentionProblem, ExecCtx, SparseAttentionOp};
use fused3s::runtime::buffers::Arg;
use fused3s::runtime::{Manifest, Runtime};
use fused3s::util::cli::Args;
use fused3s::util::prng::Rng;
use fused3s::{BITMAP_WORDS, TCB_C, TCB_R};
use std::time::Instant;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env()?;
    let name = args.get_or("dataset", "github-sim");
    let d = args.usize_or("d", 64)?;
    let rt = Runtime::from_default_artifacts()?;
    let ds = datasets::by_name(&name)?;
    let n = ds.graph.n;
    let mut rng = Rng::new(1);
    let q = rng.normal_vec(n * d, 1.0);
    let k = rng.normal_vec(n * d, 1.0);
    let v = rng.normal_vec(n * d, 1.0);
    let x = AttentionProblem::new(n, d, &q, &k, &v, 0.125);
    let driver = FusedDriver::new(rt.manifest(), &ds.graph, FusedOpts::default())?;
    let engine = Engine::serial();
    driver.execute(&mut ExecCtx::pjrt(&rt, &engine), &AttentionBatch::single(&x))?; // warm compiles

    // Manual per-bucket breakdown (mirrors the driver's bucketed path).
    let batch = rt.manifest().rw_batch;
    let mut bufs = CallBuffers::default();
    let (mut t_gather, mut t_exec, mut t_scatter) = (0.0f64, 0.0, 0.0);
    let mut per_bucket: std::collections::BTreeMap<usize, (usize, f64)> =
        Default::default();
    let mut out = vec![0.0f32; n * d];
    for call in &driver.plan.calls {
        let exe = rt.executable(&Manifest::fused3s_name(
            call.t_bucket, d, "bf16", "splitc",
        ))?;
        let t0 = Instant::now();
        gather::gather_call(&mut bufs, &call.rws, call.t_bucket, &driver.bsb, &x, batch);
        t_gather += t0.elapsed().as_secs_f64();
        let sq = [batch, TCB_R, d];
        let sk = [batch, call.t_bucket * TCB_C, d];
        let sv = [batch, call.t_bucket * TCB_C, d];
        let sbm = [batch, call.t_bucket, BITMAP_WORDS];
        let t0 = Instant::now();
        let outs = rt.run_exe_raw(
            &exe,
            &[
                Arg::F32(&bufs.q, &sq),
                Arg::F32(&bufs.k, &sk),
                Arg::F32(&bufs.v, &sv),
                Arg::I32(&bufs.bm, &sbm),
            ],
        )?;
        let dt = t0.elapsed().as_secs_f64();
        t_exec += dt;
        let e = per_bucket.entry(call.t_bucket).or_insert((0, 0.0));
        e.0 += 1;
        e.1 += dt;
        let t0 = Instant::now();
        gather::scatter_call(&mut out, outs[0].as_f32()?, &call.rws, n, d);
        t_scatter += t0.elapsed().as_secs_f64();
    }
    println!(
        "{name}: {} regular calls, {} chunked RWs",
        driver.plan.calls.len(),
        driver.plan.chunked.len()
    );
    println!(
        "gather {:.1} ms | execute {:.1} ms | scatter {:.1} ms",
        t_gather * 1e3,
        t_exec * 1e3,
        t_scatter * 1e3
    );
    for (t, (count, secs)) in per_bucket {
        println!(
            "  bucket t={t:<4} calls={count:<3} exec total {:.1} ms ({:.2} ms/call)",
            secs * 1e3,
            secs * 1e3 / count as f64
        );
    }
    Ok(())
}
