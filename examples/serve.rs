//! Serving example: the coordinator behind the TCP wire protocol
//! (DESIGN.md §13) under a batched multi-graph request stream
//! (molecule-property-style workload) — the deployment shape a 3S kernel
//! library actually runs in.
//!
//! A loopback [`NetServer`] fronts the coordinator; `--clients` threads
//! each open a real TCP connection and stream requests over a shared set
//! of repeat batched graphs, so the fingerprint handshake kicks in: each
//! graph's CSR is uploaded once per client and every later submit rides a
//! 16-byte fingerprint reference straight into the server's DriverCache.
//! Requests default to `Backend::Auto`, so the adaptive planner routes
//! each one and refines its cost model from the measured latencies
//! (`--backend fused3s` pins the old fixed routing).
//!
//! ```sh
//! make artifacts && cargo run --release --example serve -- --requests 12
//! cargo run --release --example serve -- --host   # offline host emulation
//! ```

use fused3s::coordinator::{Coordinator, CoordinatorConfig, ExecutorKind};
use fused3s::graph::batch::{batched_dataset, BatchKind};
use fused3s::graph::CsrGraph;
use fused3s::kernels::Backend;
use fused3s::net::{NetClient, NetConfig, NetServer, WireRequest};
use fused3s::util::cli::Args;
use fused3s::util::prng::Rng;
use std::sync::mpsc::channel;
use std::sync::Arc;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env()?;
    let clients = args.usize_or("clients", 3)?;
    let requests = args.usize_or("requests", 12)?; // per client
    let n_graphs = args.usize_or("graphs", 4)?;
    let d = args.usize_or("d", 64)?;
    let backend = Backend::parse(&args.get_or("backend", "auto"))?;

    let mut cfg = CoordinatorConfig {
        preprocess_workers: args.usize_or("workers", 2)?,
        ..CoordinatorConfig::default()
    };
    if args.bool("host") {
        cfg.executor = ExecutorKind::HostEmulation;
    }
    let coord = Arc::new(Coordinator::start(cfg)?);
    let server = NetServer::serve(coord.clone(), NetConfig::default())?;
    let addr = server.local_addr();
    println!(
        "listening on {addr}; {clients} clients x {requests} requests over \
         {n_graphs} repeat graphs (backend={})",
        backend.name()
    );

    // The shared workload: batches of small molecule-like graphs (the OGB
    // graph-property-prediction serving shape), reused across requests so
    // the wire handshake and the server-side plan cache both engage.
    let graphs: Arc<Vec<CsrGraph>> = Arc::new(
        (0..n_graphs)
            .map(|i| {
                let (g, _) =
                    batched_dataset(24, 10, 30, i as u64, BatchKind::Molecule);
                g.with_self_loops()
            })
            .collect(),
    );

    let t0 = std::time::Instant::now();
    let (tx, rx) = channel();
    let mut handles = Vec::new();
    for c in 0..clients {
        let graphs = graphs.clone();
        let tx = tx.clone();
        handles.push(std::thread::spawn(move || {
            let mut rng = Rng::new(0xCAFE ^ c as u64);
            let mut ok = 0usize;
            let mut first_err: Option<String> = None;
            let mut client = match NetClient::connect(addr, "") {
                Ok(cl) => cl,
                Err(e) => {
                    let _ = tx.send((0, 0, 0, 0, Some(e.to_string())));
                    return;
                }
            };
            for r in 0..requests {
                let g = &graphs[(c + r) % graphs.len()];
                let nd = g.n * d;
                let q = rng.normal_vec(nd, 1.0);
                let k = rng.normal_vec(nd, 1.0);
                let v = rng.normal_vec(nd, 1.0);
                let req = WireRequest::single_head(
                    (c * requests + r) as u64,
                    g,
                    d,
                    &q,
                    &k,
                    &v,
                    1.0 / (d as f32).sqrt(),
                    backend,
                );
                match client.submit(&req) {
                    Ok(resp) if resp.result.is_ok() => ok += 1,
                    Ok(resp) => {
                        if let Err(e) = resp.result {
                            first_err.get_or_insert(e.to_string());
                        }
                    }
                    Err(e) => {
                        first_err.get_or_insert(e.to_string());
                    }
                }
            }
            let s = client.stats();
            client.close();
            let _ = tx.send((
                ok,
                s.graph_uploads,
                s.upload_skips,
                s.graph_bytes_naive - s.graph_bytes_uploaded,
                first_err,
            ));
        }));
    }
    drop(tx);

    let (mut ok, mut uploads, mut skips, mut saved) = (0usize, 0u64, 0u64, 0u64);
    let mut first_err = None;
    while let Ok((o, u, sk, sv, e)) = rx.recv() {
        ok += o;
        uploads += u;
        skips += sk;
        saved += sv;
        if let Some(e) = e {
            first_err.get_or_insert(e);
        }
    }
    for h in handles {
        let _ = h.join();
    }
    let total = clients * requests;
    let wall = t0.elapsed().as_secs_f64();
    println!(
        "served {ok}/{total} over TCP in {wall:.2}s = {:.1} req/s",
        ok as f64 / wall
    );
    println!(
        "fingerprint handshake: {uploads} CSR uploads, {skips} reference \
         submits, {saved} topology bytes saved"
    );
    if let Some(e) = first_err {
        println!("first failure: {e}");
    }
    println!("{}", coord.metrics().report());

    // Per-stage latency breakdown, fetched the way an external operator
    // would: a fresh wire client issuing MetricsQuery (DESIGN.md §15)
    // rather than reaching into the in-process Metrics.
    match NetClient::connect(addr, "").and_then(|mut c| {
        let m = c.metrics();
        c.close();
        m
    }) {
        Ok(m) => print_stage_table(&m),
        Err(e) => println!("metrics query failed: {e}"),
    }

    server.shutdown();
    coord.shutdown();
    Ok(())
}

/// Render the wire metrics report's latency sections as a stage table.
fn print_stage_table(m: &fused3s::util::json::Json) {
    let ms = |stage: &str, field: &str| -> String {
        m.req(stage)
            .and_then(|s| s.req(field))
            .and_then(|v| v.as_f64())
            .map(|s| format!("{:.2}", s * 1e3))
            .unwrap_or_else(|_| "-".into())
    };
    let count = |stage: &str| -> String {
        m.req(stage)
            .and_then(|s| s.req("count"))
            .and_then(|v| v.as_f64())
            .map(|c| format!("{c:.0}"))
            .unwrap_or_else(|_| "-".into())
    };
    println!(
        "{:<12} {:>8} {:>10} {:>10} {:>10} {:>10}",
        "stage", "count", "p50 ms", "p95 ms", "p99 ms", "max ms"
    );
    for stage in ["latency", "preprocess", "execute"] {
        println!(
            "{:<12} {:>8} {:>10} {:>10} {:>10} {:>10}",
            stage,
            count(stage),
            ms(stage, "p50_s"),
            ms(stage, "p95_s"),
            ms(stage, "p99_s"),
            ms(stage, "max_s"),
        );
    }
}
