//! Serving example: the coordinator under a batched multi-graph request
//! stream (molecule-property-style workload), reporting throughput and
//! latency percentiles — the deployment shape a 3S kernel library
//! actually runs in.  Requests default to `Backend::Auto`, so the adaptive
//! planner routes each one and refines its cost model from the measured
//! latencies (`--backend fused3s` pins the old fixed routing).
//!
//! ```sh
//! make artifacts && cargo run --release --example serve -- --requests 48
//! ```

use fused3s::coordinator::{AttnRequest, Coordinator, CoordinatorConfig};
use fused3s::graph::batch::{batched_dataset, BatchKind};
use fused3s::kernels::Backend;
use fused3s::util::cli::Args;
use fused3s::util::prng::Rng;
use std::sync::mpsc::channel;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env()?;
    let requests = args.usize_or("requests", 48)?;
    let d = args.usize_or("d", 64)?;
    let backend = Backend::parse(&args.get_or("backend", "auto"))?;

    let coord = Coordinator::start(CoordinatorConfig {
        preprocess_workers: args.usize_or("workers", 2)?,
        ..CoordinatorConfig::default()
    })?;
    println!(
        "coordinator up; streaming {requests} batched-graph requests \
         (backend={})",
        backend.name()
    );

    let mut rng = Rng::new(0xCAFE);
    let (tx, rx) = channel();
    let t0 = std::time::Instant::now();
    for i in 0..requests {
        // Each request: a batch of small molecule-like graphs (the OGB
        // graph-property-prediction serving shape).
        let batch_size = rng.range(16, 64);
        let (g, _) = batched_dataset(batch_size, 10, 30, i as u64, BatchKind::Molecule);
        let g = g.with_self_loops();
        let nd = g.n * d;
        coord.submit(AttnRequest::single_head(
            i as u64,
            g,
            d,
            rng.normal_vec(nd, 1.0),
            rng.normal_vec(nd, 1.0),
            rng.normal_vec(nd, 1.0),
            1.0 / (d as f32).sqrt(),
            backend,
            tx.clone(),
        ))?;
    }
    drop(tx);

    let mut ok = 0usize;
    let mut first_err = None;
    while let Ok(resp) = rx.recv() {
        match resp.result {
            Ok(_) => ok += 1,
            Err(e) => {
                first_err.get_or_insert(e);
            }
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    println!(
        "served {ok}/{requests} in {wall:.2}s = {:.1} req/s",
        ok as f64 / wall
    );
    if let Some(e) = first_err {
        println!("first failure: {e}");
    }
    println!("{}", coord.metrics().report());
    let prep = coord.metrics().preprocess.snapshot();
    let exec = coord.metrics().execute.snapshot();
    println!(
        "stage p50: preprocess {:.2} ms, execute {:.2} ms",
        prep.p50_s * 1e3,
        exec.p50_s * 1e3
    );
    coord.shutdown();
    Ok(())
}
