"""L2 — the Graph Transformer compute graph as fixed-shape tile programs.

The end-to-end model of the paper's §4.4 is the Graph Transformer of
Dwivedi & Bresson [5]: 10 blocks, each

    h  ->  LN(h + O_proj(MultiHeadSparseAttention(h)))   (attention sub-block)
       ->  LN(h' + W2 · relu(W1 h' + b1) + b2)           (FFN sub-block)

The sparse attention itself runs through the L1 Fused3S kernel (or one of the
baseline kernels — that is the experiment of Fig. 8).  Everything dense is
expressed here as *row-tile* programs over a fixed tile of ``m`` rows: the
Rust model runtime (`rust/src/model/`) walks a graph's N rows in tiles of m,
dispatching each tile to the corresponding AOT executable.  This keeps every
artifact shape static while supporting arbitrary graph sizes — the same
bucketing idea used for the sparse kernel.

Head convention: d_head = 32, n_heads = d / 32 (so d ∈ {64, 128, 256} of
Fig. 8 give 2/4/8 heads).  Heads are folded into the kernel's row-window
batch axis by the Rust coordinator; no head axis appears here.

Mixed precision mirrors the kernel: bf16 GEMM inputs, f32 accumulation,
f32 LayerNorm statistics.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

D_HEAD = 32  # head width shared with rust/src/model/gt.rs


def _mm(x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """bf16 GEMM with f32 accumulation (the MXU-shaped primitive)."""
    return jax.lax.dot_general(
        x.astype(jnp.bfloat16),
        w.astype(jnp.bfloat16),
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )


@jax.jit
def qkv_proj(x: jnp.ndarray, wqkv: jnp.ndarray, bqkv: jnp.ndarray):
    """Fused Q/K/V projection: one (m,d)x(d,3d) GEMM instead of three.

    Returns (m, 3d) f32; the Rust side slices Q|K|V and splits heads.
    """
    return _mm(x, wqkv) + bqkv[None, :]


@jax.jit
def linear(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray):
    """Plain affine map (used for the attention output projection)."""
    return _mm(x, w) + b[None, :]


@jax.jit
def ffn(x, w1, b1, w2, b2):
    """Position-wise FFN: relu(x W1 + b1) W2 + b2, hidden = 2d (GT default).

    Both GEMMs and the activation are fused into one executable — one
    dispatch per row tile instead of three (see DESIGN.md §9 L2 targets).
    """
    h = jnp.maximum(_mm(x, w1) + b1[None, :], 0.0)
    return _mm(h, w2) + b2[None, :]


@jax.jit
def add_layernorm(x, y, gamma, beta):
    """LN(x + y) — the residual-add and LayerNorm of each sub-block, fused.

    Statistics in f32 over the feature axis, eps = 1e-5 (DGL default).
    """
    z = x.astype(jnp.float32) + y.astype(jnp.float32)
    mu = jnp.mean(z, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(z - mu), axis=-1, keepdims=True)
    zhat = (z - mu) * jax.lax.rsqrt(var + 1e-5)
    return zhat * gamma[None, :] + beta[None, :]


@jax.jit
def layernorm(x, gamma, beta):
    """Plain LayerNorm (input embedding normalisation)."""
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + 1e-5) * gamma[None, :] + beta[None, :]


@jax.jit
def row_normalize(x):
    """L2-normalise rows — the AGNN (Eq. 3) cosine-similarity preprocessing."""
    n = jnp.sqrt(jnp.sum(jnp.square(x), axis=-1, keepdims=True))
    return x / jnp.where(n > 0, n, 1.0)


# ---------------------------------------------------------------------------
# Pure-jnp reference of a whole GT block (used by tests and to cross-check the
# Rust runtime's orchestration on small graphs).
# ---------------------------------------------------------------------------


def gt_block_ref(h, adj_mask, params, *, n_heads: int):
    """One Graph Transformer block over a whole (small) graph, f32 oracle.

    params: dict with wqkv (d,3d), bqkv, wo (d,d), bo, w1 (d,2d), b1,
    w2 (2d,d), b2, g1, be1, g2, be2.
    """
    from .kernels.ref import dense_attention_ref

    n, d = h.shape
    dh = d // n_heads
    qkv = h @ params["wqkv"] + params["bqkv"]
    q, k, v = qkv[:, :d], qkv[:, d : 2 * d], qkv[:, 2 * d :]
    heads = []
    for i in range(n_heads):
        sl = slice(i * dh, (i + 1) * dh)
        heads.append(
            dense_attention_ref(
                q[:, sl], k[:, sl], v[:, sl], adj_mask, scale=1.0 / dh**0.5
            )
        )
    att = jnp.concatenate(heads, axis=1)
    att = att @ params["wo"] + params["bo"]
    h1 = _ln_ref(h + att, params["g1"], params["be1"])
    f = jnp.maximum(h1 @ params["w1"] + params["b1"], 0.0)
    f = f @ params["w2"] + params["b2"]
    return _ln_ref(h1 + f, params["g2"], params["be2"])


def _ln_ref(x, gamma, beta):
    mu = x.mean(axis=-1, keepdims=True)
    var = ((x - mu) ** 2).mean(axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + 1e-5) * gamma + beta


# ---------------------------------------------------------------------------
# Input specs for the AOT manifest.
# ---------------------------------------------------------------------------


def qkv_proj_spec(m: int, d: int):
    return [((m, d), "f32"), ((d, 3 * d), "f32"), ((3 * d,), "f32")]


def linear_spec(m: int, din: int, dout: int):
    return [((m, din), "f32"), ((din, dout), "f32"), ((dout,), "f32")]


def ffn_spec(m: int, d: int, h: int):
    return [
        ((m, d), "f32"),
        ((d, h), "f32"),
        ((h,), "f32"),
        ((h, d), "f32"),
        ((d,), "f32"),
    ]


def add_layernorm_spec(m: int, d: int):
    return [((m, d), "f32"), ((m, d), "f32"), ((d,), "f32"), ((d,), "f32")]


def layernorm_spec(m: int, d: int):
    return [((m, d), "f32"), ((d,), "f32"), ((d,), "f32")]


def row_normalize_spec(m: int, d: int):
    return [((m, d), "f32")]
