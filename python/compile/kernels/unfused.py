"""Unfused 3S baselines — the FlashSparse / PyG execution model.

FlashSparse [32] (and the DGL/PyG framework path) runs the 3S pattern as
*separate kernels*, materialising the attention-score matrix S and the
normalised matrix E in HBM between stages:

    S = SDDMM(Q, K, A)      # kernel 1, S written to HBM
    E = softmax(S)          # kernel 2, S read + E written
    O = SpMM(E, V)          # kernel 3, E read

This module provides those three stages as independent jittable functions so
``aot.py`` can lower each one into its *own* executable.  The Rust driver
(`rust/src/kernels/unfused.rs`) round-trips the intermediates through host
buffers between the three PJRT executions — reproducing the exact data-
movement penalty the paper's fusion removes.

Two softmax variants mirror the paper's FlashSparse comparison (§4.1):

* ``softmax_naive``  — no max subtraction.  Faster (no row-max reduction)
  but overflows once any score exceeds ~88 in f32 (§3.5).
* ``softmax_stable`` — max-stabilised, the fair-comparison variant.

All stages run over the same BSB block layout as the fused kernel so the
comparison isolates *fusion*, not format.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from .ref import BITMAP_WORDS, TCB_C, TCB_R

NEG_INF = float("-inf")


def _block_mask(bitmap: jnp.ndarray, t: int) -> jnp.ndarray:
    """(B, t, 4) i32 bitmaps -> (B, 16, t*8) bool mask, pure jnp (no numpy)."""
    b = bitmap.shape[0]
    idx = (
        jax.lax.broadcasted_iota(jnp.int32, (TCB_R, TCB_C), 0) * TCB_C
        + jax.lax.broadcasted_iota(jnp.int32, (TCB_R, TCB_C), 1)
    )
    word_idx = jax.lax.shift_right_logical(idx, 5)
    bit_idx = jnp.bitwise_and(idx, 31)
    w = jnp.zeros((b, t, TCB_R, TCB_C), jnp.int32)
    for i in range(BITMAP_WORDS):
        w = jnp.where(word_idx[None, None] == i, bitmap[:, :, i, None, None], w)
    bits = jnp.bitwise_and(jax.lax.shift_right_logical(w, bit_idx[None, None]), 1)
    mask = bits == 1  # (B, t, 16, 8)
    return jnp.transpose(mask, (0, 2, 1, 3)).reshape(b, TCB_R, t * TCB_C)


@functools.partial(jax.jit, static_argnames=("t", "scale", "precision"))
def sddmm(
    q: jnp.ndarray,
    khat: jnp.ndarray,
    bitmap: jnp.ndarray,
    *,
    t: int,
    scale: float = 1.0,
    precision: str = "bf16",
) -> jnp.ndarray:
    """Stage 1: S = (Q K̂^T) * scale, masked to -inf outside the bitmap.

    Returns (B, 16, t*8) f32 — the materialised score matrix (the paper's
    point: this write is what fusion eliminates).
    """
    dt = jnp.bfloat16 if precision == "bf16" else jnp.float32
    s = jax.lax.dot_general(
        q.astype(dt),
        khat.astype(dt),
        dimension_numbers=(((2,), (2,)), ((0,), (0,))),
        preferred_element_type=jnp.float32,
    )
    if scale != 1.0:
        s = s * scale
    mask = _block_mask(bitmap, t)
    return jnp.where(mask, s, NEG_INF)


@jax.jit
def softmax_naive(s: jnp.ndarray) -> jnp.ndarray:
    """Stage 2 (naive): E = exp(S) / rowsum(exp(S)).

    No max subtraction — mirrors FlashSparse's original softmax.  exp(-inf)=0
    handles masking, but any score > ~88 overflows f32 to inf and the row
    becomes NaN; the stability experiment (`repro stability`) demonstrates
    exactly this failure mode.
    """
    e = jnp.exp(s)
    denom = jnp.sum(e, axis=-1, keepdims=True)
    return e / denom


@jax.jit
def softmax_stable(s: jnp.ndarray) -> jnp.ndarray:
    """Stage 2 (stable): max-subtracted softmax with the empty-row->0 rule."""
    m = jnp.max(s, axis=-1, keepdims=True)
    m_safe = jnp.where(jnp.isfinite(m), m, 0.0)
    e = jnp.where(jnp.isfinite(s), jnp.exp(s - m_safe), 0.0)
    denom = jnp.sum(e, axis=-1, keepdims=True)
    return jnp.where(denom > 0, e / jnp.where(denom > 0, denom, 1.0), 0.0)


@functools.partial(jax.jit, static_argnames=("precision",))
def spmm(
    e: jnp.ndarray,
    vhat: jnp.ndarray,
    *,
    precision: str = "bf16",
) -> jnp.ndarray:
    """Stage 3: O = E V̂ (block-sparse aggregation), f32 accumulate."""
    dt = jnp.bfloat16 if precision == "bf16" else jnp.float32
    return jax.lax.dot_general(
        e.astype(dt),
        vhat.astype(dt),
        dimension_numbers=(((2,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.float32,
    )


def unfused_3s(
    q: jnp.ndarray,
    khat: jnp.ndarray,
    vhat: jnp.ndarray,
    bitmap: jnp.ndarray,
    *,
    t: int,
    scale: float = 1.0,
    stable: bool = True,
    precision: str = "bf16",
) -> jnp.ndarray:
    """Convenience composition of the three stages (tests / oracles only —
    the benchmarked path executes the three artifacts separately)."""
    s = sddmm(q, khat, bitmap, t=t, scale=scale, precision=precision)
    e = softmax_stable(s) if stable else softmax_naive(s)
    return spmm(e, vhat, precision=precision)


@functools.partial(jax.jit, static_argnames=("scale", "precision"))
def dense_attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    mask: jnp.ndarray,
    *,
    scale: float = 1.0,
    precision: str = "f32",
) -> jnp.ndarray:
    """Whole-graph dense masked attention (PyG-like dense fallback and the
    graph-scale verification oracle).  mask is (N, N) i32 (0/1)."""
    dt = jnp.bfloat16 if precision == "bf16" else jnp.float32
    s = jax.lax.dot_general(
        q.astype(dt), k.astype(dt),
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    if scale != 1.0:
        s = s * scale
    s = jnp.where(mask == 1, s, NEG_INF)
    m = jnp.max(s, axis=-1, keepdims=True)
    m_safe = jnp.where(jnp.isfinite(m), m, 0.0)
    e = jnp.where(mask == 1, jnp.exp(s - m_safe), 0.0)
    denom = jnp.sum(e, axis=-1, keepdims=True)
    e = jnp.where(denom > 0, e / jnp.where(denom > 0, denom, 1.0), 0.0)
    return jax.lax.dot_general(
        e.astype(dt), v.astype(dt),
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )


def sddmm_spec(b: int, t: int, d: int):
    return [
        ((b, TCB_R, d), "f32"),
        ((b, t * TCB_C, d), "f32"),
        ((b, t, BITMAP_WORDS), "i32"),
    ]


def softmax_spec(b: int, t: int):
    return [((b, TCB_R, t * TCB_C), "f32")]


def spmm_spec(b: int, t: int, d: int):
    return [
        ((b, TCB_R, t * TCB_C), "f32"),
        ((b, t * TCB_C, d), "f32"),
    ]


def dense_spec(n: int, d: int):
    return [
        ((n, d), "f32"),
        ((n, d), "f32"),
        ((n, d), "f32"),
        ((n, n), "i32"),
    ]
