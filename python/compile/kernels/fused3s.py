"""Fused3S — the paper's Algorithm 1 as a Pallas kernel.

One kernel fuses the three sparse-attention operations (the "3S" pattern):

  1. SDDMM      S_j = Q_i K̂_j^T  ⊙ bitmap_j          (tensor-core GEMM)
  2. softmax    online, max-stabilized, f32           (Alg. 1 lines 16-18)
  3. SpMM       O_i += diag(rescale) O_i + E_j V̂_j    (tensor-core GEMM)

Grid layout (node-parallel fusion, §3.2 of the paper): one program instance
per *row window* (RW) of r=16 rows.  The paper maps an RW to a CUDA thread
block; we map it to a Pallas grid step.

TPU adaptation of the TCB loop (see DESIGN.md §Hardware-Adaptation): the
paper walks 16×8 TCBs with per-tile `mma` ops because that is the tensor
core's operand shape.  The MXU wants *wide, batched* contractions, so one
Pallas program processes the **whole batch of row windows in a single
pass**: one batched (B,16,d)x(B,d,t*8) SDDMM contraction, a masked row
softmax over all t TCBs at once, and one batched (B,16,t*8)x(B,t*8,dv)
SpMM contraction.  The paper's thread-block axis becomes the GEMM batch
dim; its split-column warp axis becomes the wide N axis.  S and E still
never leave the kernel (the fusion claim), and the *online* softmax
survives where it is actually needed under AOT static shapes: combining
partial states across the chunks of oversize row windows
(`fused3s_partial` + the Rust-side merge), which generalises the paper's
"multiple thread blocks per row window" future-work item.  (An earlier
revision used grid=(B,) with a per-TCB fori_loop — measured 3–30× slower
on the CPU substrate and a poor MXU shape; see EXPERIMENTS.md §Perf.)

Static-shape contract (AOT bucketing, see DESIGN.md §1): every executable is
specialised to a TCB count ``t`` and feature dim ``d``; the Rust coordinator
routes each RW to the smallest bucket with t >= its TCB count and pads with
all-zero bitmaps.  Zero bitmaps mask to -inf and exponentiate to 0, so padding
is numerically exact.

Mixed precision (paper Table 5, fp16→bf16 for TPU): Q/K̂/V̂ are cast to bf16
for the GEMMs, accumulation and the whole softmax run in f32, E is cast to
bf16 before the SpMM contraction, O is f32.

All kernels are built with ``interpret=True``: the CPU PJRT plugin cannot run
Mosaic custom-calls, so the kernel is lowered to plain HLO.  Real-TPU VMEM /
MXU estimates live in DESIGN.md §7.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import BITMAP_WORDS, TCB_C, TCB_R

NEG_INF = float("-inf")


def _expand_bitmap(words: jnp.ndarray) -> jnp.ndarray:
    """Expand one TCB bitmap (4 x i32 words) into a (16, 8) bool mask.

    Bit ``i = row*8 + col`` of the 128-bit map lives in word ``i // 32`` at
    position ``i % 32``.  There is no dynamic gather: the word for each lane is
    selected with four equality-masked broadcasts (constant unrolled), which
    lowers to vector selects — the TPU analog of the paper's "bitmap decoded
    in registers, no index arithmetic".
    """
    idx = (
        jax.lax.broadcasted_iota(jnp.int32, (TCB_R, TCB_C), 0) * TCB_C
        + jax.lax.broadcasted_iota(jnp.int32, (TCB_R, TCB_C), 1)
    )
    word_idx = jax.lax.shift_right_logical(idx, 5)
    bit_idx = jnp.bitwise_and(idx, 31)
    w = jnp.zeros((TCB_R, TCB_C), jnp.int32)
    for i in range(BITMAP_WORDS):
        w = jnp.where(word_idx == i, words[i], w)
    bit = jnp.bitwise_and(jax.lax.shift_right_logical(w, bit_idx), 1)
    return bit == 1


def _expand_bitmaps_batch(words: jnp.ndarray, b: int, t: int) -> jnp.ndarray:
    """Expand a batch of row-window bitmaps (B, t, 4) -> (B, 16, t*8) bool.

    Same single-bit arithmetic as :func:`_expand_bitmap`, vectorised over
    the batch and TCB axes so the kernel decodes every block in one shot.
    """
    idx = (
        jax.lax.broadcasted_iota(jnp.int32, (TCB_R, TCB_C), 0) * TCB_C
        + jax.lax.broadcasted_iota(jnp.int32, (TCB_R, TCB_C), 1)
    )  # (16, 8): bit index within any block
    word_idx = jax.lax.shift_right_logical(idx, 5)  # (16, 8)
    bit_idx = jnp.bitwise_and(idx, 31)
    # Select each lane's word per (batch, TCB): (B, t, 16, 8).
    w = jnp.zeros((b, t, TCB_R, TCB_C), jnp.int32)
    for i in range(BITMAP_WORDS):
        w = jnp.where(word_idx[None, None] == i, words[:, :, i, None, None], w)
    bit = jnp.bitwise_and(
        jax.lax.shift_right_logical(w, bit_idx[None, None]), 1
    )
    mask = bit == 1  # (B, t, 16, 8)
    return jnp.transpose(mask, (0, 2, 1, 3)).reshape(b, TCB_R, t * TCB_C)


def _finalize(acc: jnp.ndarray, l: jnp.ndarray) -> jnp.ndarray:
    """O_i = diag(l)^-1 acc with the empty-row (l == 0) -> 0 convention."""
    safe_l = jnp.where(l > 0, l, 1.0)
    return jnp.where((l > 0)[:, None], acc / safe_l[:, None], 0.0)


def _leaky_relu(x: jnp.ndarray, slope: float = 0.2) -> jnp.ndarray:
    """LeakyReLU pre-softmax activation — lets the same kernel express GAT
    (Eq. 2 of the paper) where scores pass through LeakyReLU before softmax."""
    return jnp.where(x >= 0, x, slope * x)


def _masked_softmax_rows(s, mask):
    """Row softmax over the masked score strips; empty rows -> (p=0, l=0)."""
    s = jnp.where(mask, s, NEG_INF)
    m = jnp.max(s, axis=-1)
    m_safe = jnp.where(jnp.isneginf(m), 0.0, m)
    p = jnp.where(mask, jnp.exp(s - m_safe[..., None]), 0.0)
    l = jnp.sum(p, axis=-1)
    return p, m, l


def _finalize_batch(acc, l):
    """O = diag(l)^-1 acc with the empty-row (l == 0) -> 0 convention."""
    safe_l = jnp.where(l > 0, l, 1.0)
    return jnp.where((l > 0)[..., None], acc / safe_l[..., None], 0.0)


def _sddmm_batch(q, k, compute_dtype):
    """(B,16,d) x (B,t*8,d) -> (B,16,t*8), f32 accumulate."""
    return jax.lax.dot_general(
        q.astype(compute_dtype),
        k.astype(compute_dtype),
        dimension_numbers=(((2,), (2,)), ((0,), (0,))),
        preferred_element_type=jnp.float32,
    )


def _spmm_batch(p, v, compute_dtype):
    """(B,16,t*8) x (B,t*8,dv) -> (B,16,dv), f32 accumulate."""
    return jax.lax.dot_general(
        p.astype(compute_dtype),
        v.astype(compute_dtype),
        dimension_numbers=(((2,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.float32,
    )


def _fused3s_kernel(q_ref, k_ref, v_ref, bm_ref, o_ref, *, t: int, scale: float,
                    compute_dtype, activation: str = "none"):
    """Single-pass fused 3S over a batch of row windows (module docstring)."""
    b = q_ref.shape[0]
    s = _sddmm_batch(q_ref[...], k_ref[...], compute_dtype)
    if scale != 1.0:
        s = s * scale
    if activation == "leakyrelu":
        s = _leaky_relu(s)
    mask = _expand_bitmaps_batch(bm_ref[...], b, t)
    p, _, l = _masked_softmax_rows(s, mask)
    pv = _spmm_batch(p, v_ref[...], compute_dtype)
    o_ref[...] = _finalize_batch(pv, l)


def _fused3s_splitr_kernel(q_ref, k_ref, v_ref, bm_ref, o_ref, *, t: int,
                           scale: float, compute_dtype,
                           activation: str = "none", dk: int = 32):
    """Split-row ablation variant (paper §3.3, F3S_splitR).

    The paper's split-row scheme partitions the contraction (feature) axis of
    each S-tile across warps, forcing every warp to hold only a fragment of
    Q_i and requiring a cross-warp reduction per tile.  Structural analog:
    the SDDMM contraction is decomposed into d/dk partial-depth products
    reduced sequentially — narrower GEMMs plus an explicit reduction instead
    of one full-depth contraction.
    """
    b = q_ref.shape[0]
    d = q_ref.shape[-1]
    q = q_ref[...]
    k = k_ref[...]
    n_frag = max(1, d // dk)
    s = jnp.zeros((b, TCB_R, t * TCB_C), jnp.float32)
    for f in range(n_frag):
        qf = jax.lax.slice_in_dim(q, f * dk, (f + 1) * dk, axis=2)
        kf = jax.lax.slice_in_dim(k, f * dk, (f + 1) * dk, axis=2)
        s = s + _sddmm_batch(qf, kf, compute_dtype)
    if scale != 1.0:
        s = s * scale
    if activation == "leakyrelu":
        s = _leaky_relu(s)
    mask = _expand_bitmaps_batch(bm_ref[...], b, t)
    p, _, l = _masked_softmax_rows(s, mask)
    pv = _spmm_batch(p, v_ref[...], compute_dtype)
    o_ref[...] = _finalize_batch(pv, l)


@functools.partial(
    jax.jit,
    static_argnames=("t", "scale", "variant", "precision", "activation"),
)
def fused3s(
    q: jnp.ndarray,
    khat: jnp.ndarray,
    vhat: jnp.ndarray,
    bitmap: jnp.ndarray,
    *,
    t: int,
    scale: float = 1.0,
    variant: str = "splitc",
    precision: str = "bf16",
    activation: str = "none",
) -> jnp.ndarray:
    """Fused sparse attention over BSB row-window blocks.

    Args:
      q:      (B, 16, d) f32 row-window query blocks.
      khat:   (B, t*8, d) f32 gathered key rows (zero-padded per bucket).
      vhat:   (B, t*8, d) f32 gathered value rows.
      bitmap: (B, t, 4) i32 TCB bitmaps (zero words = fully masked padding).
      t:      TCB-count bucket (static).
      scale:  score scale baked into the executable (static).
      variant:   "splitc" (default, paper's choice) or "splitr" (ablation).
      precision: "bf16" (paper's mixed precision) or "f32" (DF-GNN analog).

    Returns:
      (B, 16, d) f32 output blocks.
    """
    b, r, d = q.shape
    dv = vhat.shape[-1]
    assert r == TCB_R, q.shape
    assert khat.shape == (b, t * TCB_C, d), (khat.shape, (b, t * TCB_C, d))
    assert vhat.shape == (b, t * TCB_C, dv), vhat.shape
    assert bitmap.shape == (b, t, BITMAP_WORDS), bitmap.shape
    compute_dtype = jnp.bfloat16 if precision == "bf16" else jnp.float32
    body = _fused3s_kernel if variant == "splitc" else _fused3s_splitr_kernel
    kernel = functools.partial(
        body, t=t, scale=scale, compute_dtype=compute_dtype,
        activation=activation,
    )
    # One program instance covers the whole row-window batch (batched GEMMs
    # are the MXU-friendly shape; the RW axis is the GEMM batch dim).  On a
    # real TPU a BlockSpec over the batch axis would stream RWs through
    # VMEM; interpret mode runs the program whole.
    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((b, TCB_R, dv), jnp.float32),
        interpret=True,  # CPU PJRT cannot execute Mosaic custom-calls
    )(q, khat, vhat, bitmap)


def fused3s_spec(b: int, t: int, d: int, dv: int | None = None):
    """(shapes, dtypes) of the executable's inputs, for the AOT manifest."""
    dv = d if dv is None else dv
    return [
        ((b, TCB_R, d), "f32"),
        ((b, t * TCB_C, d), "f32"),
        ((b, t * TCB_C, dv), "f32"),
        ((b, t, BITMAP_WORDS), "i32"),
    ]


def default_scale(d: int) -> float:
    """1/sqrt(d) — the transformer-head convention used by the GT model."""
    return 1.0 / math.sqrt(d)


# ---------------------------------------------------------------------------
# Partial (chunked) variant — oversize row windows.
#
# Row windows whose TCB count exceeds the largest compiled bucket (Reddit-
# style mega-hubs, Table 7's 9857-TCB tail) are split into chunks; each chunk
# runs this kernel, which returns the *normalised* chunk output plus its
# online-softmax state (m, l).  The Rust coordinator merges chunk results:
#
#   w_i = l_i * exp(m_i - max_j m_j);   O = sum_i w_i O_i / sum_i w_i
#
# This is the online-softmax identity across chunks — the host-side analog of
# the paper's "multiple thread blocks per row window" future-work item, and
# exactly the flash-decoding split-KV combine.
# ---------------------------------------------------------------------------


def _fused3s_partial_kernel(q_ref, k_ref, v_ref, bm_ref, o_ref, m_ref, l_ref,
                            *, t: int, scale: float, compute_dtype):
    """Single-pass chunk kernel: normalised chunk outputs + softmax states."""
    b = q_ref.shape[0]
    s = _sddmm_batch(q_ref[...], k_ref[...], compute_dtype)
    if scale != 1.0:
        s = s * scale
    mask = _expand_bitmaps_batch(bm_ref[...], b, t)
    p, m, l = _masked_softmax_rows(s, mask)
    pv = _spmm_batch(p, v_ref[...], compute_dtype)
    o_ref[...] = _finalize_batch(pv, l)
    m_ref[...] = m
    l_ref[...] = l


@functools.partial(jax.jit, static_argnames=("t", "scale", "precision"))
def fused3s_partial(
    q: jnp.ndarray,
    khat: jnp.ndarray,
    vhat: jnp.ndarray,
    bitmap: jnp.ndarray,
    *,
    t: int,
    scale: float = 1.0,
    precision: str = "bf16",
):
    """Chunk kernel: returns (o, m, l) per row-window chunk.

    Shapes as :func:`fused3s`; extra outputs m, l are (B, 16) f32.
    """
    b, r, d = q.shape
    dv = vhat.shape[-1]
    assert r == TCB_R
    compute_dtype = jnp.bfloat16 if precision == "bf16" else jnp.float32
    kernel = functools.partial(
        _fused3s_partial_kernel, t=t, scale=scale, compute_dtype=compute_dtype
    )
    return pl.pallas_call(
        kernel,
        out_shape=[
            jax.ShapeDtypeStruct((b, TCB_R, dv), jnp.float32),
            jax.ShapeDtypeStruct((b, TCB_R), jnp.float32),
            jax.ShapeDtypeStruct((b, TCB_R), jnp.float32),
        ],
        interpret=True,
    )(q, khat, vhat, bitmap)


def merge_partials(os, ms, ls):
    """Reference implementation of the host-side chunk merge (numpy).

    The Rust coordinator reimplements this; `test_chunking.py` pins both
    against the unchunked kernel.  os: list of (16, dv); ms, ls: list of (16,).
    """
    import numpy as np

    ms_arr = np.stack(ms)            # (C, 16)
    m_max = ms_arr.max(axis=0)       # (16,)
    m_safe = np.where(np.isfinite(m_max), m_max, 0.0)
    w = np.stack(ls) * np.exp(ms_arr - m_safe)  # (C, 16)
    denom = w.sum(axis=0)            # (16,)
    num = (w[:, :, None] * np.stack(os)).sum(axis=0)  # (16, dv)
    return np.where(denom[:, None] > 0,
                    num / np.where(denom[:, None] > 0, denom[:, None], 1.0),
                    0.0)
