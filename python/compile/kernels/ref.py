"""Pure-jnp correctness oracles for the Fused3S kernel.

Two oracles:

* :func:`dense_attention_ref` — the textbook formulation of Eq. (1) of the
  paper, ``O = softmax(Q K^T * scale  (masked by A)) V`` over the *whole*
  graph.  This is the ground truth everything else is measured against.

* :func:`bsb_attention_ref` — the same computation expressed over the BSB
  block layout the Rust coordinator hands to the kernel (per-row-window Q
  blocks, gathered K̂ / V̂ block stacks, 128-bit TCB bitmaps).  It is written
  with plain ``jnp`` ops and *global* (not online) softmax, so it exercises
  the data layout without sharing any code with the Pallas kernel.

Conventions (shared with the Rust side — keep in sync with
``rust/src/bsb/bitmap.rs``):

* TCB shape is r=16 rows by c=8 columns.
* A TCB bitmap is four little-endian u32 words; bit index ``i = row * 8 + col``
  lives in word ``i // 32`` at bit ``i % 32``.
* Rows with no unmasked entries produce an all-zero output row (softmax over
  the empty set is defined as 0, matching the paper's graphs where isolated
  rows simply aggregate nothing).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

TCB_R = 16
TCB_C = 8
BITMAP_WORDS = (TCB_R * TCB_C) // 32  # = 4


def expand_bitmap_np(words: np.ndarray) -> np.ndarray:
    """Expand a (..., 4) uint32/int32 bitmap array to a (..., 16, 8) bool mask.

    NumPy variant used by tests and by the oracle below.
    """
    words = np.asarray(words).astype(np.uint32)
    assert words.shape[-1] == BITMAP_WORDS, words.shape
    idx = np.arange(TCB_R * TCB_C).reshape(TCB_R, TCB_C)
    word_idx = idx // 32
    bit_idx = idx % 32
    w = words[..., word_idx]  # (..., 16, 8)
    return ((w >> bit_idx) & 1).astype(bool)


def pack_bitmap_np(mask: np.ndarray) -> np.ndarray:
    """Pack a (..., 16, 8) bool mask into (..., 4) int32 bitmap words."""
    mask = np.asarray(mask, dtype=bool)
    assert mask.shape[-2:] == (TCB_R, TCB_C), mask.shape
    flat = mask.reshape(mask.shape[:-2] + (TCB_R * TCB_C,))
    out = np.zeros(mask.shape[:-2] + (BITMAP_WORDS,), dtype=np.uint32)
    for i in range(TCB_R * TCB_C):
        out[..., i // 32] |= flat[..., i].astype(np.uint32) << np.uint32(i % 32)
    # int32 view: rust passes i32 words; bit patterns are identical.
    return out.view(np.int32)


def masked_softmax(s: jnp.ndarray, mask: jnp.ndarray) -> jnp.ndarray:
    """Row-wise max-stabilized softmax over unmasked entries; empty rows -> 0."""
    neg = jnp.where(mask, s, -jnp.inf)
    m = jnp.max(neg, axis=-1, keepdims=True)
    m_safe = jnp.where(jnp.isfinite(m), m, 0.0)
    e = jnp.where(mask, jnp.exp(neg - m_safe), 0.0)
    denom = jnp.sum(e, axis=-1, keepdims=True)
    return jnp.where(denom > 0, e / jnp.where(denom > 0, denom, 1.0), 0.0)


def dense_attention_ref(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    mask: jnp.ndarray,
    scale: float = 1.0,
) -> jnp.ndarray:
    """O = softmax(scale * Q K^T ⊙ mask) V with the empty-row-is-zero convention.

    Args:
      q, k, v: (N, d) float arrays.
      mask:    (N, N) bool adjacency / attention mask.
      scale:   multiplicative score scale (1/sqrt(d) for transformer heads).
    """
    s = (q.astype(jnp.float32) @ k.astype(jnp.float32).T) * scale
    e = masked_softmax(s, mask)
    return e @ v.astype(jnp.float32)


def bsb_attention_ref(
    q_blk: jnp.ndarray,
    khat: jnp.ndarray,
    vhat: jnp.ndarray,
    bitmap: jnp.ndarray,
    scale: float = 1.0,
) -> jnp.ndarray:
    """Global-softmax oracle over the BSB block layout.

    Args:
      q_blk:  (B, 16, d) row-window Q blocks.
      khat:   (B, T*8, d) gathered K rows (T TCBs of 8 compacted columns).
      vhat:   (B, T*8, d) gathered V rows.
      bitmap: (B, T, 4) int32 TCB bitmaps.
    Returns:
      (B, 16, d) float32 output blocks.
    """
    b, r, d = q_blk.shape
    t = bitmap.shape[1]
    assert r == TCB_R
    assert khat.shape == (b, t * TCB_C, d), (khat.shape, b, t, d)
    mask = jnp.asarray(expand_bitmap_np(np.asarray(bitmap)))  # (B, T, 16, 8)
    mask = jnp.transpose(mask, (0, 2, 1, 3)).reshape(b, TCB_R, t * TCB_C)
    s = jnp.einsum(
        "brd,bcd->brc",
        q_blk.astype(jnp.float32),
        khat.astype(jnp.float32),
    ) * scale
    e = masked_softmax(s, mask)
    return jnp.einsum("brc,bcd->brd", e, vhat.astype(jnp.float32))


def bsb_attention_ref_mixed(
    q_blk: jnp.ndarray,
    khat: jnp.ndarray,
    vhat: jnp.ndarray,
    bitmap: jnp.ndarray,
    scale: float = 1.0,
) -> jnp.ndarray:
    """Like :func:`bsb_attention_ref` but with the paper's mixed-precision
    pipeline (Table 5, fp16→bf16): bf16 matmul inputs, f32 accumulation,
    f32 softmax, E cast to bf16 before SpMM.  Used to bound the error the
    Pallas kernel is allowed to have."""
    b, r, d = q_blk.shape
    t = bitmap.shape[1]
    mask = jnp.asarray(expand_bitmap_np(np.asarray(bitmap)))
    mask = jnp.transpose(mask, (0, 2, 1, 3)).reshape(b, TCB_R, t * TCB_C)
    s = jax.lax.dot_general(
        q_blk.astype(jnp.bfloat16),
        khat.astype(jnp.bfloat16),
        dimension_numbers=(((2,), (2,)), ((0,), (0,))),
        preferred_element_type=jnp.float32,
    ) * scale
    e = masked_softmax(s, mask).astype(jnp.bfloat16)
    return jax.lax.dot_general(
        e,
        vhat.astype(jnp.bfloat16),
        dimension_numbers=(((2,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.float32,
    )
