"""Fused 3S backward pass — the paper's §6 extension, implemented.

The forward kernel computes ``O = softmax(S) V`` with ``S = QK̂ᵀ ⊙ bitmap``.
Given upstream gradients dO, the backward involves exactly the operations
the paper names — "SpMM and SDDMM … in reverse order":

    dV  = Eᵀ dO                                  (SpMM, transposed)
    dP  = dO V̂ᵀ            masked by the bitmap  (SDDMM shape)
    dS  = E ⊙ (dP − rowsum(dP ⊙ E))              (softmax backward)
    dQ  = dS K̂ · scale                           (SpMM)
    dK̂ = dSᵀ Q · scale                           (SpMM, transposed)

All five stay fused in one Pallas program per row-window batch, with the
same BSB bitmap masking and static-bucket contract as the forward kernel.
E is recomputed from (Q, K̂, bitmap) inside the kernel — the
FlashAttention-2 recomputation strategy — so nothing besides the forward
inputs and dO crosses HBM.

Scatter note: dK̂/dV̂ are gradients w.r.t. the *gathered* rows; the Rust
coordinator scatter-adds them back to dK/dV rows (a column can appear in
many row windows, so the host reduction mirrors the forward gather).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .fused3s import (
    _expand_bitmaps_batch,
    _masked_softmax_rows,
    _sddmm_batch,
    _spmm_batch,
)
from .ref import BITMAP_WORDS, TCB_C, TCB_R


def _fused3s_bwd_kernel(q_ref, k_ref, v_ref, bm_ref, do_ref,
                        dq_ref, dk_ref, dv_ref, *, t: int, scale: float,
                        compute_dtype):
    b = q_ref.shape[0]
    q = q_ref[...]
    k = k_ref[...]
    v = v_ref[...]
    do = do_ref[...]
    # --- recompute E (forward softmax), f32 ---
    s = _sddmm_batch(q, k, compute_dtype)
    if scale != 1.0:
        s = s * scale
    mask = _expand_bitmaps_batch(bm_ref[...], b, t)
    p, _, l = _masked_softmax_rows(s, mask)
    safe_l = jnp.where(l > 0, l, 1.0)
    e = jnp.where((l > 0)[..., None], p / safe_l[..., None], 0.0)  # (B,16,t*8)

    # --- dV = Eᵀ dO : (B,t*8,16) x (B,16,dv) ---
    dv = jax.lax.dot_general(
        e.astype(compute_dtype),
        do.astype(compute_dtype),
        dimension_numbers=(((1,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.float32,
    )
    # --- dP = dO V̂ᵀ (SDDMM shape: only masked entries matter) ---
    dp = jax.lax.dot_general(
        do.astype(compute_dtype),
        v.astype(compute_dtype),
        dimension_numbers=(((2,), (2,)), ((0,), (0,))),
        preferred_element_type=jnp.float32,
    )
    dp = jnp.where(mask, dp, 0.0)
    # --- softmax backward: dS = E ⊙ (dP − rowsum(dP ⊙ E)) ---
    row = jnp.sum(dp * e, axis=-1, keepdims=True)
    ds = e * (dp - row)
    if scale != 1.0:
        ds = ds * scale
    # --- dQ = dS K̂ : (B,16,t*8) x (B,t*8,d) ---
    dq = _spmm_batch(ds, k, compute_dtype)
    # --- dK̂ = dSᵀ Q : (B,t*8,16) x (B,16,d) ---
    dk = jax.lax.dot_general(
        ds.astype(compute_dtype),
        q.astype(compute_dtype),
        dimension_numbers=(((1,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.float32,
    )
    dq_ref[...] = dq
    dk_ref[...] = dk
    dv_ref[...] = dv


@functools.partial(jax.jit, static_argnames=("t", "scale", "precision"))
def fused3s_bwd(
    q: jnp.ndarray,
    khat: jnp.ndarray,
    vhat: jnp.ndarray,
    bitmap: jnp.ndarray,
    do: jnp.ndarray,
    *,
    t: int,
    scale: float = 1.0,
    precision: str = "bf16",
):
    """Fused backward over BSB row-window blocks.

    Args match :func:`fused3s.fused3s` plus ``do`` (B, 16, dv) upstream
    gradients.  Returns (dq, dkhat, dvhat) with the forward input shapes;
    dkhat/dvhat are per-gathered-row and must be scatter-added by column id.
    """
    b, r, d = q.shape
    dv_dim = vhat.shape[-1]
    assert r == TCB_R
    assert khat.shape == (b, t * TCB_C, d)
    assert vhat.shape == (b, t * TCB_C, dv_dim)
    assert bitmap.shape == (b, t, BITMAP_WORDS)
    assert do.shape == (b, TCB_R, dv_dim)
    compute_dtype = jnp.bfloat16 if precision == "bf16" else jnp.float32
    kernel = functools.partial(
        _fused3s_bwd_kernel, t=t, scale=scale, compute_dtype=compute_dtype
    )
    return pl.pallas_call(
        kernel,
        out_shape=[
            jax.ShapeDtypeStruct((b, TCB_R, d), jnp.float32),
            jax.ShapeDtypeStruct((b, t * TCB_C, d), jnp.float32),
            jax.ShapeDtypeStruct((b, t * TCB_C, dv_dim), jnp.float32),
        ],
        interpret=True,
    )(q, khat, vhat, bitmap, do)


def fused3s_bwd_spec(b: int, t: int, d: int, dv: int | None = None):
    """Manifest input spec (forward inputs + dO)."""
    dv = d if dv is None else dv
    return [
        ((b, TCB_R, d), "f32"),
        ((b, t * TCB_C, d), "f32"),
        ((b, t * TCB_C, dv), "f32"),
        ((b, t, BITMAP_WORDS), "i32"),
        ((b, TCB_R, dv), "f32"),
    ]
