"""AOT pipeline: lower the L1/L2 suite to HLO-text artifacts + manifest.

Run once by ``make artifacts``:

    cd python && python -m compile.aot --out ../artifacts

Python never runs again after this: the Rust coordinator loads the HLO text
via ``HloModuleProto::from_text_file`` and compiles it on the PJRT CPU client.

Interchange format is **HLO text**, not ``.serialize()``: jax ≥ 0.5 emits
protos with 64-bit instruction ids which xla_extension 0.5.1 rejects
(``proto.id() <= INT_MAX``); the text parser reassigns ids and round-trips
cleanly (see /opt/xla-example/README.md).

The artifact *suite* implements the bucketing contract of DESIGN.md §1:

* ``fused3s_t{T}_d{D}``            — the paper's kernel, per TCB bucket T and
                                     feature dim D, bf16 mixed precision.
* ``fused3s_f32nc_t{T}_d{D}``      — f32 variant (DF-GNN analog; the "nc" =
                                     used with the no-compaction BSB build).
* ``fused3s_splitr_t{T}_d{D}``     — split-row warp-partition ablation.
* ``fused3s_gat_t{T}_dv{D}``       — LeakyReLU rank-2 score variant for GAT.
* ``sddmm_* / softmax_* / spmm_*`` — the unfused FlashSparse-analog stages.
* ``dense_n{N}_d{D}``              — whole-graph dense attention (PyG dense
                                     fallback + graph-scale oracle).
* ``qkv_proj_* / linear_* / ffn_* / add_ln_* / ln_*`` — GT row-tile ops.
* ``fused3s_bwd_*``                — the fused backward pass (paper §6
                                     future work): dV/dP/dS/dQ/dK̂ in one
                                     program, E recomputed in-kernel.

Every artifact gets a manifest entry with its input shapes/dtypes so the Rust
runtime can validate buffers before execution.
"""

from __future__ import annotations

import argparse
import functools
import json
import os
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model
from .kernels import fused3s as f3s
from .kernels import fused3s_bwd as f3s_bwd
from .kernels import unfused
from .kernels.ref import BITMAP_WORDS, TCB_C, TCB_R

# ---------------------------------------------------------------------------
# Suite configuration — kept small enough to lower in minutes, wide enough to
# cover every experiment in DESIGN.md §3.  The Rust side reads these from the
# manifest, so changing them here reconfigures the whole stack.
# ---------------------------------------------------------------------------

RW_BATCH = 8                       # row windows per dispatch (swept 2-64, see EXPERIMENTS.md §Perf)
T_BUCKETS = [4, 8, 16, 32, 64, 128]
D_KERNEL = [32, 64, 128]           # 3S kernel feature dims
D_MODEL = [64, 128, 256]           # GT embedding dims (Fig. 8)
M_TILE = 1024                      # rows per dense-op tile
DENSE_N = [256, 1024]              # dense-attention graph sizes
DENSE_D = [32, 64]
GAT_T = [4, 8, 16, 32]
CHUNK_T = 128                      # chunk capacity for oversize row windows
GAT_DV = [64]
SPLITR_D = 64                      # split-row ablation feature dim
F32_D = [32, 64]                   # DF-GNN analog dims (32 = GT head width)


def _spec_dtype(s: str):
    return {"f32": jnp.float32, "i32": jnp.int32}[s]


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


class Suite:
    def __init__(self, out_dir: str):
        self.out_dir = out_dir
        self.entries = []
        self.t0 = time.time()

    def add(self, name: str, fn, in_specs, params: dict, n_outputs: int = 1):
        """Lower ``fn`` at the given input specs and write ``<name>.hlo.txt``."""
        args = [
            jax.ShapeDtypeStruct(shape, _spec_dtype(dt))
            for shape, dt in in_specs
        ]
        lowered = jax.jit(fn).lower(*args)
        text = to_hlo_text(lowered)
        path = os.path.join(self.out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        self.entries.append(
            {
                "name": name,
                "file": f"{name}.hlo.txt",
                "params": params,
                "inputs": [
                    {"shape": list(shape), "dtype": dt}
                    for shape, dt in in_specs
                ],
                "n_outputs": n_outputs,
            }
        )
        print(
            f"[{time.time() - self.t0:7.1f}s] {name}  "
            f"({len(text) / 1024:.0f} KiB)",
            flush=True,
        )

    def write_manifest(self):
        manifest = {
            "version": 1,
            "rw_batch": RW_BATCH,
            "t_buckets": T_BUCKETS,
            "d_kernel": D_KERNEL,
            "d_model": D_MODEL,
            "m_tile": M_TILE,
            "chunk_t": CHUNK_T,
            "d_head": model.D_HEAD,
            "tcb_r": TCB_R,
            "tcb_c": TCB_C,
            "bitmap_words": BITMAP_WORDS,
            "executables": self.entries,
        }
        path = os.path.join(self.out_dir, "manifest.json")
        with open(path, "w") as f:
            json.dump(manifest, f, indent=1)
        print(f"wrote {path} ({len(self.entries)} executables)")


def build_fused3s(suite: Suite, fast: bool):
    t_buckets = T_BUCKETS if not fast else [4, 8]
    d_kernel = D_KERNEL if not fast else [32]
    for t in t_buckets:
        for d in d_kernel:
            fn = functools.partial(
                f3s.fused3s, t=t, scale=1.0, variant="splitc", precision="bf16"
            )
            suite.add(
                f"fused3s_t{t}_d{d}",
                fn,
                f3s.fused3s_spec(RW_BATCH, t, d),
                dict(kind="fused3s", t=t, d=d, dv=d, b=RW_BATCH,
                     precision="bf16", variant="splitc"),
            )
    # DF-GNN analog: fused but f32 end-to-end.
    for t in t_buckets:
        for d in F32_D if not fast else [32]:
            fn = functools.partial(
                f3s.fused3s, t=t, scale=1.0, variant="splitc", precision="f32"
            )
            suite.add(
                f"fused3s_f32nc_t{t}_d{d}",
                fn,
                f3s.fused3s_spec(RW_BATCH, t, d),
                dict(kind="fused3s", t=t, d=d, dv=d, b=RW_BATCH,
                     precision="f32", variant="splitc"),
            )
    # Split-row ablation (all buckets so any graph can run it).
    for t, d in ([(t, SPLITR_D) for t in t_buckets] if not fast else [(4, 32)]):
        fn = functools.partial(
            f3s.fused3s, t=t, scale=1.0, variant="splitr", precision="bf16"
        )
        suite.add(
            f"fused3s_splitr_t{t}_d{d}",
            fn,
            f3s.fused3s_spec(RW_BATCH, t, d),
            dict(kind="fused3s", t=t, d=d, dv=d, b=RW_BATCH,
                 precision="bf16", variant="splitr"),
        )
    # Partial (chunked) kernel for row windows beyond the largest bucket:
    # returns (o, m, l) so the Rust coordinator can merge chunk softmax
    # states (flash-decoding-style combine; see fused3s.merge_partials).
    for d in d_kernel:
        fn = functools.partial(
            f3s.fused3s_partial, t=CHUNK_T if not fast else 8, scale=1.0,
            precision="bf16",
        )
        tc = CHUNK_T if not fast else 8
        suite.add(
            f"fused3s_partial_t{tc}_d{d}",
            fn,
            f3s.fused3s_spec(RW_BATCH, tc, d),
            dict(kind="fused3s_partial", t=tc, d=d, dv=d, b=RW_BATCH,
                 precision="bf16"),
            n_outputs=3,
        )
    # Backward pass (paper §6 extension): subset of buckets for training
    # experiments; E recomputed in-kernel (FlashAttention-2 strategy).
    for t in ([8, 32] if not fast else [4]):
        for d in ([32, 64] if not fast else [32]):
            fn = functools.partial(
                f3s_bwd.fused3s_bwd, t=t, scale=1.0, precision="bf16"
            )
            suite.add(
                f"fused3s_bwd_t{t}_d{d}",
                fn,
                f3s_bwd.fused3s_bwd_spec(RW_BATCH, t, d),
                dict(kind="fused3s_bwd", t=t, d=d, dv=d, b=RW_BATCH,
                     precision="bf16"),
                n_outputs=3,
            )
    # GAT: rank-2 scores (d=2) + LeakyReLU, value dim dv.
    for t in GAT_T if not fast else [4]:
        for dv in GAT_DV:
            fn = functools.partial(
                f3s.fused3s, t=t, scale=1.0, variant="splitc",
                precision="bf16", activation="leakyrelu",
            )
            suite.add(
                f"fused3s_gat_t{t}_dv{dv}",
                fn,
                f3s.fused3s_spec(RW_BATCH, t, 2, dv),
                dict(kind="fused3s", t=t, d=2, dv=dv, b=RW_BATCH,
                     precision="bf16", variant="splitc",
                     activation="leakyrelu"),
            )


def build_unfused(suite: Suite, fast: bool):
    t_buckets = T_BUCKETS if not fast else [4, 8]
    d_kernel = ([32, 64] if not fast else [32])
    for t in t_buckets:
        for d in d_kernel:
            suite.add(
                f"sddmm_t{t}_d{d}",
                functools.partial(unfused.sddmm, t=t, scale=1.0),
                unfused.sddmm_spec(RW_BATCH, t, d),
                dict(kind="sddmm", t=t, d=d, b=RW_BATCH),
            )
            suite.add(
                f"spmm_t{t}_d{d}",
                unfused.spmm,
                unfused.spmm_spec(RW_BATCH, t, d),
                dict(kind="spmm", t=t, d=d, b=RW_BATCH),
            )
        suite.add(
            f"softmax_naive_t{t}",
            unfused.softmax_naive,
            unfused.softmax_spec(RW_BATCH, t),
            dict(kind="softmax_naive", t=t, b=RW_BATCH),
        )
        suite.add(
            f"softmax_stable_t{t}",
            unfused.softmax_stable,
            unfused.softmax_spec(RW_BATCH, t),
            dict(kind="softmax_stable", t=t, b=RW_BATCH),
        )


def build_dense(suite: Suite, fast: bool):
    for n in DENSE_N if not fast else [256]:
        for d in DENSE_D if not fast else [32]:
            suite.add(
                f"dense_n{n}_d{d}",
                functools.partial(unfused.dense_attention, scale=1.0),
                unfused.dense_spec(n, d),
                dict(kind="dense", n=n, d=d),
            )


def build_model_ops(suite: Suite, fast: bool):
    for d in D_MODEL if not fast else [64]:
        m = M_TILE
        suite.add(
            f"qkv_proj_m{m}_d{d}",
            model.qkv_proj,
            model.qkv_proj_spec(m, d),
            dict(kind="qkv_proj", m=m, d=d),
        )
        suite.add(
            f"linear_m{m}_d{d}",
            model.linear,
            model.linear_spec(m, d, d),
            dict(kind="linear", m=m, din=d, dout=d),
        )
        suite.add(
            f"ffn_m{m}_d{d}",
            model.ffn,
            model.ffn_spec(m, d, 2 * d),
            dict(kind="ffn", m=m, d=d, h=2 * d),
        )
        suite.add(
            f"add_ln_m{m}_d{d}",
            model.add_layernorm,
            model.add_layernorm_spec(m, d),
            dict(kind="add_ln", m=m, d=d),
        )
        suite.add(
            f"ln_m{m}_d{d}",
            model.layernorm,
            model.layernorm_spec(m, d),
            dict(kind="ln", m=m, d=d),
        )
    # AGNN preprocessing (row normalisation) at kernel dims.
    for d in ([64] if not fast else [32]):
        suite.add(
            f"row_norm_m{M_TILE}_d{d}",
            model.row_normalize,
            model.row_normalize_spec(M_TILE, d),
            dict(kind="row_norm", m=M_TILE, d=d),
        )


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument(
        "--fast", action="store_true",
        help="tiny suite for CI smoke runs (subset of buckets)",
    )
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)
    suite = Suite(args.out)
    build_fused3s(suite, args.fast)
    build_unfused(suite, args.fast)
    build_dense(suite, args.fast)
    build_model_ops(suite, args.fast)
    suite.write_manifest()


if __name__ == "__main__":
    main()
