"""L2 tile ops and the GT block reference: shapes + numerics."""

import numpy as np
import pytest

from compile import model


def rand(rng, *shape):
    return rng.standard_normal(shape).astype(np.float32)


def test_qkv_proj_matches_numpy():
    rng = np.random.default_rng(0)
    # 1/sqrt(fan-in) weight scale (realistic init) keeps outputs O(1) so the
    # bf16-GEMM tolerance is meaningful.
    x, w, b = rand(rng, 64, 32), rand(rng, 32, 96) / np.sqrt(32), rand(rng, 96)
    out = np.asarray(model.qkv_proj(x, w, b))
    np.testing.assert_allclose(out, x @ w + b, rtol=3e-2, atol=3e-2)
    assert out.shape == (64, 96)


def test_linear_matches_numpy():
    rng = np.random.default_rng(1)
    x, w, b = rand(rng, 16, 64), rand(rng, 64, 64) / np.sqrt(64), rand(rng, 64)
    out = np.asarray(model.linear(x, w, b))
    np.testing.assert_allclose(out, x @ w + b, rtol=3e-2, atol=3e-2)


def test_ffn_matches_numpy():
    rng = np.random.default_rng(2)
    d, h = 32, 64
    x = rand(rng, 8, d)
    w1, b1 = rand(rng, d, h) / np.sqrt(d), rand(rng, h)
    w2, b2 = rand(rng, h, d) / np.sqrt(h), rand(rng, d)
    out = np.asarray(model.ffn(x, w1, b1, w2, b2))
    ref = np.maximum(x @ w1 + b1, 0) @ w2 + b2
    np.testing.assert_allclose(out, ref, rtol=5e-2, atol=5e-2)


def test_add_layernorm():
    rng = np.random.default_rng(3)
    x, y = rand(rng, 10, 64), rand(rng, 10, 64)
    g, b = rand(rng, 64), rand(rng, 64)
    out = np.asarray(model.add_layernorm(x, y, g, b))
    z = x + y
    mu = z.mean(-1, keepdims=True)
    var = z.var(-1, keepdims=True)
    ref = (z - mu) / np.sqrt(var + 1e-5) * g + b
    np.testing.assert_allclose(out, ref, rtol=1e-4, atol=1e-4)
    # LN output is standardised before affine
    raw = np.asarray(
        model.add_layernorm(x, y, np.ones(64, np.float32), np.zeros(64, np.float32))
    )
    np.testing.assert_allclose(raw.mean(-1), 0.0, atol=1e-5)
    np.testing.assert_allclose(raw.std(-1), 1.0, atol=1e-3)


def test_row_normalize():
    rng = np.random.default_rng(4)
    x = rand(rng, 12, 32)
    x[3] = 0.0  # zero row must stay zero, not NaN
    out = np.asarray(model.row_normalize(x))
    norms = np.linalg.norm(out, axis=-1)
    np.testing.assert_allclose(norms[np.arange(12) != 3], 1.0, rtol=1e-5)
    np.testing.assert_array_equal(out[3], np.zeros(32, np.float32))


def make_gt_params(rng, d):
    return {
        "wqkv": rand(rng, d, 3 * d) / np.sqrt(d),
        "bqkv": np.zeros(3 * d, np.float32),
        "wo": rand(rng, d, d) / np.sqrt(d),
        "bo": np.zeros(d, np.float32),
        "w1": rand(rng, d, 2 * d) / np.sqrt(d),
        "b1": np.zeros(2 * d, np.float32),
        "w2": rand(rng, 2 * d, d) / np.sqrt(2 * d),
        "b2": np.zeros(d, np.float32),
        "g1": np.ones(d, np.float32),
        "be1": np.zeros(d, np.float32),
        "g2": np.ones(d, np.float32),
        "be2": np.zeros(d, np.float32),
    }


@pytest.mark.parametrize("n_heads", [1, 2])
def test_gt_block_ref_runs_and_is_finite(n_heads):
    rng = np.random.default_rng(5)
    n, d = 32, 64
    h = rand(rng, n, d)
    adj = rng.random((n, n)) < 0.2
    np.fill_diagonal(adj, True)
    params = make_gt_params(rng, d)
    out = np.asarray(model.gt_block_ref(h, adj, params, n_heads=n_heads))
    assert out.shape == (n, d)
    assert np.isfinite(out).all()
    # LayerNorm at the output: rows standardised (unit gamma, zero beta)
    np.testing.assert_allclose(out.mean(-1), 0.0, atol=1e-4)


def test_gt_block_attention_masked():
    """A node with only a self-loop must aggregate only itself."""
    rng = np.random.default_rng(6)
    n, d = 16, 64
    h = rand(rng, n, d)
    adj = np.zeros((n, n), bool)
    np.fill_diagonal(adj, True)  # self-loops only -> attention is identity agg
    params = make_gt_params(rng, d)
    out = np.asarray(model.gt_block_ref(h, adj, params, n_heads=2))
    # with self-loops only, softmax weight per row is exactly 1 on itself:
    # attention output == V == h @ wv; verify via manual pipeline
    d_ = d
    qkv = h @ params["wqkv"]
    v = qkv[:, 2 * d_ :]
    att = v @ params["wo"]
    z = h + att
    mu, var = z.mean(-1, keepdims=True), z.var(-1, keepdims=True)
    h1 = (z - mu) / np.sqrt(var + 1e-5)
    f = np.maximum(h1 @ params["w1"], 0) @ params["w2"]
    z2 = h1 + f
    mu2, var2 = z2.mean(-1, keepdims=True), z2.var(-1, keepdims=True)
    ref = (z2 - mu2) / np.sqrt(var2 + 1e-5)
    np.testing.assert_allclose(out, ref, rtol=1e-3, atol=1e-3)
