"""Bitmap pack/expand round-trip — the BSB encoding contract shared with
``rust/src/bsb/bitmap.rs``.  If these conventions drift the whole stack
silently computes the wrong sparsity pattern, so they are pinned here."""

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile.kernels import ref


def test_constants():
    assert ref.TCB_R == 16
    assert ref.TCB_C == 8
    assert ref.BITMAP_WORDS == 4


def test_empty_bitmap():
    words = np.zeros((4,), np.int32)
    assert not ref.expand_bitmap_np(words).any()


def test_full_bitmap():
    words = np.full((4,), -1, np.int32)  # all bits set
    assert ref.expand_bitmap_np(words).all()


def test_single_bit_positions():
    # bit i = row*8+col -> word i//32, bit i%32
    for row, col in [(0, 0), (0, 7), (3, 7), (4, 0), (15, 7), (8, 3)]:
        i = row * 8 + col
        words = np.zeros((4,), np.uint32)
        words[i // 32] = np.uint32(1) << np.uint32(i % 32)
        mask = ref.expand_bitmap_np(words.view(np.int32))
        assert mask[row, col]
        assert mask.sum() == 1


def test_pack_expand_roundtrip_dense_grid():
    rng = np.random.default_rng(7)
    for density in [0.0, 0.1, 0.5, 0.9, 1.0]:
        mask = rng.random((5, 3, 16, 8)) < density
        words = ref.pack_bitmap_np(mask)
        assert words.shape == (5, 3, 4)
        assert words.dtype == np.int32
        back = ref.expand_bitmap_np(words)
        np.testing.assert_array_equal(back, mask)


@settings(max_examples=50, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_pack_expand_roundtrip_property(seed):
    rng = np.random.default_rng(seed)
    mask = rng.random((2, 2, 16, 8)) < rng.random()
    np.testing.assert_array_equal(
        ref.expand_bitmap_np(ref.pack_bitmap_np(mask)), mask
    )


def test_popcount_matches_nnz():
    rng = np.random.default_rng(3)
    mask = rng.random((4, 6, 16, 8)) < 0.37
    words = ref.pack_bitmap_np(mask).view(np.uint32)
    pop = np.array(
        [bin(int(w)).count("1") for w in words.reshape(-1)]
    ).reshape(words.shape)
    np.testing.assert_array_equal(pop.sum(axis=-1), mask.sum(axis=(-2, -1)))


def test_kernel_expand_matches_numpy():
    """The in-kernel (jax) bitmap decoder agrees with the numpy oracle."""
    import jax.numpy as jnp

    from compile.kernels.fused3s import _expand_bitmap

    rng = np.random.default_rng(11)
    for _ in range(8):
        mask = rng.random((16, 8)) < rng.random()
        words = ref.pack_bitmap_np(mask[None, None])[0, 0]
        out = np.asarray(_expand_bitmap(jnp.asarray(words)))
        np.testing.assert_array_equal(out, mask)
