"""Fused3S Pallas kernel vs pure-jnp oracle — the core correctness signal.

The kernel runs mixed precision (bf16 GEMMs, f32 softmax/accumulate), so the
tolerance against the *f32* oracle is bf16-level (~1e-2 relative); against the
mixed-precision oracle it must agree tightly.  The f32 kernel variant must
match the f32 oracle to f32 tolerance.
"""

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile.kernels import fused3s as f3s
from compile.kernels import ref

from .conftest import make_problem

# The kernel's bf16 GEMMs perturb scores by ~0.5%% of |s|; softmax then
# amplifies that exponentially, so vs the *f32* oracle the honest bound is
# loose (measured worst ~7e-2 on std-normal inputs).  Algorithmic correctness
# is pinned tightly against the *mixed-precision* oracle (same rounding, but
# global instead of online softmax): measured worst ~8e-3.
MIXED_TOL = dict(rtol=2e-2, atol=2e-2)
F32_LOOSE = dict(rtol=1.5e-1, atol=1.5e-1)
F32_TOL = dict(rtol=1e-5, atol=1e-5)


def run_case(seed, b, t, d, density, scale=1.0, pad_blocks=0, value_scale=1.0,
             variant="splitc", precision="bf16"):
    rng = np.random.default_rng(seed)
    q, kh, vh, bm, _ = make_problem(
        rng, b, t, d, density, value_scale=value_scale, pad_blocks=pad_blocks
    )
    out = np.asarray(
        f3s.fused3s(q, kh, vh, bm, t=t, scale=scale, variant=variant,
                    precision=precision)
    )
    oracle_f32 = np.asarray(ref.bsb_attention_ref(q, kh, vh, bm, scale=scale))
    oracle_mixed = np.asarray(
        ref.bsb_attention_ref_mixed(q, kh, vh, bm, scale=scale)
    )
    return out, oracle_mixed, oracle_f32


@pytest.mark.parametrize("t", [1, 2, 4, 8, 16, 32])
@pytest.mark.parametrize("d", [32, 64, 128])
def test_shapes_sweep(t, d):
    out, oracle, oracle_f32 = run_case(seed=t * 100 + d, b=2, t=t, d=d, density=0.3)
    np.testing.assert_allclose(out, oracle, **MIXED_TOL)
    np.testing.assert_allclose(out, oracle_f32, **F32_LOOSE)


@pytest.mark.parametrize("density", [0.02, 0.1, 0.5, 0.95, 1.0])
def test_density_sweep(density):
    out, oracle, oracle_f32 = run_case(seed=17, b=3, t=8, d=64, density=density)
    np.testing.assert_allclose(out, oracle, **MIXED_TOL)
    np.testing.assert_allclose(out, oracle_f32, **F32_LOOSE)


@pytest.mark.parametrize("scale", [1.0, 0.125, 0.0883883])
def test_scale(scale):
    out, oracle, oracle_f32 = run_case(seed=5, b=2, t=4, d=64, density=0.4, scale=scale)
    np.testing.assert_allclose(out, oracle, **MIXED_TOL)
    np.testing.assert_allclose(out, oracle_f32, **F32_LOOSE)


@pytest.mark.parametrize("pad_blocks", [1, 3, 7])
def test_bucket_padding_exact(pad_blocks):
    """Padding TCBs with zero bitmaps must not perturb the result at all:
    compare a padded problem against the same problem in a smaller bucket."""
    rng = np.random.default_rng(23)
    t_real = 8 - pad_blocks if pad_blocks < 8 else 1
    t = 8
    q, kh, vh, bm, mask = make_problem(rng, 2, t, 64, 0.4, pad_blocks=pad_blocks)
    out_pad = np.asarray(f3s.fused3s(q, kh, vh, bm, t=t))
    # Re-run in the tight bucket (strip padded blocks).
    kh2 = kh[:, : (t - pad_blocks) * 8]
    vh2 = vh[:, : (t - pad_blocks) * 8]
    bm2 = bm[:, : t - pad_blocks]
    out_tight = np.asarray(f3s.fused3s(q, kh2, vh2, bm2, t=t - pad_blocks))
    # Padded lanes contribute exact zeros; only the XLA tree-reduction
    # order differs with the wider strip, so the bound is ~1 ulp.
    np.testing.assert_allclose(out_pad, out_tight, rtol=1e-6, atol=1e-6)


def test_fully_masked_window_is_zero():
    rng = np.random.default_rng(3)
    q, kh, vh, _, _ = make_problem(rng, 1, 2, 32, 0.5)
    bm = np.zeros((1, 2, 4), np.int32)
    out = np.asarray(f3s.fused3s(q, kh, vh, bm, t=2))
    assert not np.isnan(out).any()
    np.testing.assert_array_equal(out, np.zeros_like(out))


def test_single_nonzero_row_selects_value():
    """A row attending to exactly one column must output exactly that V row."""
    rng = np.random.default_rng(9)
    q, kh, vh, _, _ = make_problem(rng, 1, 3, 32, 0.0)
    mask = np.zeros((1, 3, 16, 8), bool)
    mask[0, 1, 5, 3] = True  # row 5 attends only to TCB 1, col 3
    bm = ref.pack_bitmap_np(mask)
    out = np.asarray(f3s.fused3s(q, kh, vh, bm, t=3))
    expected = vh[0, 1 * 8 + 3]
    np.testing.assert_allclose(out[0, 5], expected, rtol=1e-2, atol=1e-2)
    # all other rows empty -> 0
    others = np.delete(out[0], 5, axis=0)
    np.testing.assert_array_equal(others, np.zeros_like(others))


def test_large_logits_stable():
    """Online softmax must survive scores far beyond exp() range (§3.5)."""
    out, oracle, oracle_f32 = run_case(seed=31, b=2, t=4, d=64, density=0.4,
                           value_scale=12.0)  # scores ~ O(1000)
    assert not np.isnan(out).any() and not np.isinf(out).any()
    np.testing.assert_allclose(out, oracle, **MIXED_TOL)
    np.testing.assert_allclose(out, oracle_f32, **F32_LOOSE)


def test_online_softmax_order_invariance():
    """Permuting TCB order within a window (with matching K̂/V̂ permutation)
    must not change the output — the online rescaling is order-independent."""
    rng = np.random.default_rng(41)
    t = 6
    q, kh, vh, bm, mask = make_problem(rng, 1, t, 32, 0.4)
    out1 = np.asarray(f3s.fused3s(q, kh, vh, bm, t=t))
    perm = rng.permutation(t)
    kh_p = kh.reshape(1, t, 8, -1)[:, perm].reshape(kh.shape)
    vh_p = vh.reshape(1, t, 8, -1)[:, perm].reshape(vh.shape)
    bm_p = bm[:, perm]
    out2 = np.asarray(f3s.fused3s(q, kh_p, vh_p, bm_p, t=t))
    # Mathematically identical; numerically the running-max history changes
    # the bf16 rounding points, so the bound is bf16-level, not bitwise.
    np.testing.assert_allclose(out1, out2, rtol=1e-2, atol=1e-2)


def test_f32_variant_tight_tolerance():
    out, _, oracle_f32 = run_case(seed=13, b=2, t=8, d=64, density=0.3,
                                  precision="f32")
    np.testing.assert_allclose(out, oracle_f32, **F32_TOL)


@pytest.mark.parametrize("t,d", [(4, 32), (8, 64)])
def test_splitr_matches_splitc(t, d):
    rng = np.random.default_rng(t + d)
    q, kh, vh, bm, _ = make_problem(rng, 2, t, d, 0.3)
    a = np.asarray(f3s.fused3s(q, kh, vh, bm, t=t, variant="splitc"))
    b_ = np.asarray(f3s.fused3s(q, kh, vh, bm, t=t, variant="splitr"))
    np.testing.assert_allclose(a, b_, rtol=2e-2, atol=2e-2)
    oracle = np.asarray(ref.bsb_attention_ref_mixed(q, kh, vh, bm))
    np.testing.assert_allclose(b_, oracle, **MIXED_TOL)


def test_matches_mixed_precision_oracle_tightly():
    """Against the mixed-precision oracle the kernel differs only by the
    *online vs global* softmax accumulation order — tight f32-ish bound."""
    rng = np.random.default_rng(77)
    q, kh, vh, bm, _ = make_problem(rng, 3, 8, 64, 0.3)
    out = np.asarray(f3s.fused3s(q, kh, vh, bm, t=8))
    oracle = np.asarray(ref.bsb_attention_ref_mixed(q, kh, vh, bm))
    np.testing.assert_allclose(out, oracle, rtol=5e-3, atol=5e-3)


@settings(max_examples=25, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    t=st.integers(1, 12),
    d=st.sampled_from([32, 64]),
    density=st.floats(0.01, 1.0),
)
def test_property_random_problems(seed, t, d, density):
    """Hypothesis sweep: arbitrary (seed, t, d, density) agrees with oracle."""
    out, oracle, oracle_f32 = run_case(seed=seed, b=2, t=t, d=d, density=density)
    assert not np.isnan(out).any()
    np.testing.assert_allclose(out, oracle, **MIXED_TOL)
    np.testing.assert_allclose(out, oracle_f32, **F32_LOOSE)


def test_dense_equivalence_full_bitmap():
    """With an all-ones bitmap the BSB kernel must equal dense attention on
    the gathered sub-matrix."""
    rng = np.random.default_rng(55)
    t, d = 4, 32
    q, kh, vh, _, _ = make_problem(rng, 1, t, d, 1.0)
    bm = ref.pack_bitmap_np(np.ones((1, t, 16, 8), bool))
    out = np.asarray(f3s.fused3s(q, kh, vh, bm, t=t))
    oracle = np.asarray(
        ref.dense_attention_ref(
            q[0], kh[0], vh[0], np.ones((16, t * 8), bool)
        )
    )
    np.testing.assert_allclose(out[0], oracle, **F32_LOOSE)
