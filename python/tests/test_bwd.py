"""Fused backward kernel vs jax.grad of the reference — the §6 extension.

The oracle is automatic differentiation through the f32 BSB-layout
reference, so the backward kernel's five fused operations (SpMM/SDDMM in
reverse order + softmax backward) are checked against ground truth without
sharing any code.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels import ref
from compile.kernels.fused3s_bwd import fused3s_bwd

from .conftest import make_problem

TOL = dict(rtol=3e-2, atol=3e-2)


def grads_via_autodiff(q, kh, vh, bm, do, scale):
    """d/d{q,kh,vh} of <ref(q,kh,vh), do> via jax.grad (f32 oracle)."""

    def loss(q_, kh_, vh_):
        out = ref.bsb_attention_ref(q_, kh_, vh_, bm, scale=scale)
        return jnp.sum(out * do)

    return jax.grad(loss, argnums=(0, 1, 2))(q, kh, vh)


@pytest.mark.parametrize("t,d", [(2, 32), (4, 64), (8, 64)])
def test_bwd_matches_autodiff(t, d):
    rng = np.random.default_rng(t * 13 + d)
    q, kh, vh, bm, _ = make_problem(rng, 2, t, d, 0.3)
    do = rng.standard_normal((2, 16, d)).astype(np.float32)
    dq, dk, dv = fused3s_bwd(q, kh, vh, bm, do, t=t, scale=0.125)
    gq, gk, gv = grads_via_autodiff(q, kh, vh, bm, do, 0.125)
    np.testing.assert_allclose(np.asarray(dq), np.asarray(gq), **TOL)
    np.testing.assert_allclose(np.asarray(dk), np.asarray(gk), **TOL)
    np.testing.assert_allclose(np.asarray(dv), np.asarray(gv), **TOL)


def test_bwd_f32_tight():
    rng = np.random.default_rng(5)
    t, d = 4, 32
    q, kh, vh, bm, _ = make_problem(rng, 2, t, d, 0.4)
    do = rng.standard_normal((2, 16, d)).astype(np.float32)
    dq, dk, dv = fused3s_bwd(q, kh, vh, bm, do, t=t, precision="f32")
    gq, gk, gv = grads_via_autodiff(q, kh, vh, bm, do, 1.0)
    for got, want in [(dq, gq), (dk, gk), (dv, gv)]:
        np.testing.assert_allclose(
            np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4
        )


def test_bwd_masked_lanes_zero_grad():
    """Gradients w.r.t. fully-masked K̂/V̂ rows must be exactly zero."""
    rng = np.random.default_rng(7)
    t, d = 4, 32
    q, kh, vh, _, _ = make_problem(rng, 1, t, d, 0.0)
    mask = np.zeros((1, t, 16, 8), bool)
    mask[0, 0] = True  # only TCB 0 unmasked
    bm = ref.pack_bitmap_np(mask)
    do = rng.standard_normal((1, 16, d)).astype(np.float32)
    _, dk, dv = fused3s_bwd(q, kh, vh, bm, do, t=t)
    # TCBs 1..3 (rows 8..32 of the gathered stacks) carry no gradient.
    np.testing.assert_array_equal(np.asarray(dk)[0, 8:], 0.0)
    np.testing.assert_array_equal(np.asarray(dv)[0, 8:], 0.0)


def test_bwd_empty_rows_zero_grad():
    """Rows with no unmasked entries produce zero dQ."""
    rng = np.random.default_rng(9)
    t, d = 2, 32
    q, kh, vh, _, _ = make_problem(rng, 1, t, d, 0.0)
    mask = np.zeros((1, t, 16, 8), bool)
    mask[0, 0, 3, :] = True  # only row 3 attends
    bm = ref.pack_bitmap_np(mask)
    do = rng.standard_normal((1, 16, d)).astype(np.float32)
    dq, _, _ = fused3s_bwd(q, kh, vh, bm, do, t=t)
    zero_rows = [r for r in range(16) if r != 3]
    np.testing.assert_array_equal(np.asarray(dq)[0, zero_rows], 0.0)
    assert np.abs(np.asarray(dq)[0, 3]).max() > 0
