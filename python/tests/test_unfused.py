"""Unfused 3S baselines vs oracle, plus the §3.5 stability story:
the naive softmax must *actually fail* where the paper says it fails."""

import numpy as np
import pytest

from compile.kernels import fused3s as f3s
from compile.kernels import ref, unfused

from .conftest import make_problem

# The unfused pipeline compounds two bf16 roundings (S inputs and the
# materialised E), so its bound vs the f32 oracle is looser than the fused
# kernel's; vs the mixed-precision oracle it is tight (see below).
BF16_TOL = dict(rtol=5e-2, atol=5e-2)


@pytest.mark.parametrize("t,d", [(2, 32), (8, 64), (16, 128)])
def test_unfused_stable_matches_oracle(t, d):
    rng = np.random.default_rng(t * 7 + d)
    q, kh, vh, bm, _ = make_problem(rng, 2, t, d, 0.3)
    out = np.asarray(unfused.unfused_3s(q, kh, vh, bm, t=t, stable=True))
    oracle = np.asarray(ref.bsb_attention_ref(q, kh, vh, bm))
    np.testing.assert_allclose(out, oracle, **BF16_TOL)


def test_unfused_naive_matches_oracle_in_range():
    """Small logits: naive softmax agrees with the stable one."""
    rng = np.random.default_rng(2)
    q, kh, vh, bm, _ = make_problem(rng, 2, 4, 64, 0.4, value_scale=0.3)
    out = np.asarray(unfused.unfused_3s(q, kh, vh, bm, t=4, stable=False))
    oracle = np.asarray(ref.bsb_attention_ref(q, kh, vh, bm))
    np.testing.assert_allclose(out, oracle, **BF16_TOL)


def test_naive_softmax_overflows_large_logits():
    """§3.5: any score above ~88 overflows exp() in f32 -> NaN rows. This is
    the paper's argument for the stable/online variants — assert it happens."""
    rng = np.random.default_rng(4)
    q, kh, vh, bm, _ = make_problem(
        rng, 1, 4, 64, 0.5, value_scale=6.0, guarantee_nonempty=True
    )
    s = unfused.sddmm(q, kh, bm, t=4)
    assert float(np.asarray(s[np.isfinite(np.asarray(s))]).max()) > 89.0
    naive = np.asarray(unfused.softmax_naive(s))
    assert np.isnan(naive).any(), "expected overflow-induced NaNs"
    stable = np.asarray(unfused.softmax_stable(s))
    assert not np.isnan(stable).any()
    fused = np.asarray(f3s.fused3s(q, kh, vh, bm, t=4))
    assert not np.isnan(fused).any()


def test_stage_shapes():
    rng = np.random.default_rng(8)
    b, t, d = 3, 5, 32
    q, kh, vh, bm, _ = make_problem(rng, b, t, d, 0.3)
    s = unfused.sddmm(q, kh, bm, t=t)
    assert s.shape == (b, 16, t * 8)
    e = unfused.softmax_stable(s)
    assert e.shape == s.shape
    o = unfused.spmm(e, vh)
    assert o.shape == (b, 16, d)


def test_sddmm_masked_positions_are_neginf():
    rng = np.random.default_rng(12)
    q, kh, vh, bm, mask = make_problem(rng, 2, 3, 32, 0.25)
    s = np.asarray(unfused.sddmm(q, kh, bm, t=3))
    flat_mask = np.transpose(mask, (0, 2, 1, 3)).reshape(2, 16, 24)
    assert np.isneginf(s[~flat_mask]).all()
    assert np.isfinite(s[flat_mask]).all()


def test_softmax_rows_sum_to_one():
    rng = np.random.default_rng(21)
    q, kh, vh, bm, mask = make_problem(rng, 2, 4, 32, 0.5)
    s = unfused.sddmm(q, kh, bm, t=4)
    e = np.asarray(unfused.softmax_stable(s))
    flat_mask = np.transpose(mask, (0, 2, 1, 3)).reshape(2, 16, 32)
    row_has = flat_mask.any(axis=-1)
    sums = e.sum(axis=-1)
    np.testing.assert_allclose(sums[row_has], 1.0, rtol=1e-5)
    np.testing.assert_allclose(sums[~row_has], 0.0, atol=1e-7)


def test_dense_attention_matches_ref():
    rng = np.random.default_rng(33)
    n, d = 48, 32
    q = rng.standard_normal((n, d)).astype(np.float32)
    k = rng.standard_normal((n, d)).astype(np.float32)
    v = rng.standard_normal((n, d)).astype(np.float32)
    mask = (rng.random((n, n)) < 0.2).astype(np.int32)
    out = np.asarray(unfused.dense_attention(q, k, v, mask, scale=0.25))
    oracle = np.asarray(
        ref.dense_attention_ref(q, k, v, mask.astype(bool), scale=0.25)
    )
    np.testing.assert_allclose(out, oracle, rtol=1e-5, atol=1e-5)


def test_fused_vs_unfused_consistency():
    """The fused kernel and the 3-stage pipeline must agree (same layout,
    same precision policy) — isolates fusion as a pure perf transform."""
    rng = np.random.default_rng(61)
    q, kh, vh, bm, _ = make_problem(rng, 2, 6, 64, 0.35)
    a = np.asarray(f3s.fused3s(q, kh, vh, bm, t=6))
    b = np.asarray(unfused.unfused_3s(q, kh, vh, bm, t=6, stable=True))
    np.testing.assert_allclose(a, b, rtol=1e-2, atol=1e-2)
