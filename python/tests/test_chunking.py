"""Chunked oversize-row-window handling: the partial kernel + host merge
must reproduce the unchunked kernel exactly up to fp accumulation order."""

import numpy as np
import pytest

from compile.kernels import fused3s as f3s
from compile.kernels import ref

from .conftest import make_problem


def run_chunked(q, kh, vh, bm, t, chunk):
    n_chunks = (t + chunk - 1) // chunk
    os_, ms_, ls_ = [], [], []
    for c in range(n_chunks):
        lo_t, hi_t = c * chunk, min((c + 1) * chunk, t)
        # pad last chunk with zero bitmaps
        kh_c = np.zeros((1, chunk * 8, kh.shape[-1]), np.float32)
        vh_c = np.zeros((1, chunk * 8, vh.shape[-1]), np.float32)
        bm_c = np.zeros((1, chunk, 4), np.int32)
        kh_c[:, : (hi_t - lo_t) * 8] = kh[:, lo_t * 8 : hi_t * 8]
        vh_c[:, : (hi_t - lo_t) * 8] = vh[:, lo_t * 8 : hi_t * 8]
        bm_c[:, : hi_t - lo_t] = bm[:, lo_t:hi_t]
        o, m, l = f3s.fused3s_partial(q, kh_c, vh_c, bm_c, t=chunk)
        os_.append(np.asarray(o)[0])
        ms_.append(np.asarray(m)[0])
        ls_.append(np.asarray(l)[0])
    return f3s.merge_partials(os_, ms_, ls_)


@pytest.mark.parametrize("t,chunk", [(12, 4), (10, 4), (7, 3), (16, 8)])
def test_chunked_equals_full(t, chunk):
    rng = np.random.default_rng(t * 31 + chunk)
    q, kh, vh, bm, _ = make_problem(rng, 1, t, 64, 0.3)
    full = np.asarray(f3s.fused3s(q, kh, vh, bm, t=t))[0]
    merged = run_chunked(q, kh, vh, bm, t, chunk)
    np.testing.assert_allclose(merged, full, rtol=5e-3, atol=5e-3)


def test_chunked_with_empty_chunks():
    """Chunks that are fully masked must not perturb the merge."""
    rng = np.random.default_rng(3)
    t, chunk = 12, 4
    q, kh, vh, bm, mask = make_problem(rng, 1, t, 32, 0.4)
    mask[0, 4:8] = False  # middle chunk fully masked
    bm = ref.pack_bitmap_np(mask)
    full = np.asarray(f3s.fused3s(q, kh, vh, bm, t=t))[0]
    merged = run_chunked(q, kh, vh, bm, t, chunk)
    np.testing.assert_allclose(merged, full, rtol=5e-3, atol=5e-3)


def test_chunked_empty_rows_stay_zero():
    rng = np.random.default_rng(4)
    t, chunk = 8, 4
    q, kh, vh, _, _ = make_problem(rng, 1, t, 32, 0.0)
    mask = np.zeros((1, t, 16, 8), bool)
    mask[0, 0, 3, :] = True  # only row 3 nonzero
    bm = ref.pack_bitmap_np(mask)
    merged = run_chunked(q, kh, vh, bm, t, chunk)
    assert not np.isnan(merged).any()
    zero_rows = [r for r in range(16) if r != 3]
    np.testing.assert_array_equal(merged[zero_rows], 0.0)


def test_partial_outputs_state():
    """m/l outputs must equal the online-softmax state of the chunk."""
    rng = np.random.default_rng(5)
    q, kh, vh, bm, mask = make_problem(rng, 2, 4, 32, 0.5)
    o, m, l = f3s.fused3s_partial(q, kh, vh, bm, t=4)
    s = np.einsum("brd,bcd->brc", q, kh)
    fm = np.transpose(mask, (0, 2, 1, 3)).reshape(2, 16, 32)
    sm = np.where(fm, s, -np.inf)
    m_ref = sm.max(axis=-1)
    np.testing.assert_allclose(np.asarray(m), m_ref, rtol=1e-2, atol=1e-2)
    e = np.where(fm, np.exp(sm - np.where(np.isfinite(m_ref), m_ref, 0)[..., None]), 0)
    np.testing.assert_allclose(np.asarray(l), e.sum(-1), rtol=2e-2, atol=2e-2)
