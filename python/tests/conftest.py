"""Shared helpers for the kernel test-suite: random BSB problem generation."""

from __future__ import annotations

import numpy as np

from compile.kernels import ref


def make_problem(
    rng: np.random.Generator,
    b: int,
    t: int,
    d: int,
    density: float = 0.3,
    value_scale: float = 1.0,
    pad_blocks: int = 0,
    guarantee_nonempty: bool = False,
):
    """Build a random BSB-layout attention problem.

    Returns (q, khat, vhat, bitmap) with shapes
    (b,16,d), (b,t*8,d), (b,t*8,d), (b,t,4).

    ``pad_blocks`` forces the last ``pad_blocks`` TCBs of every window to be
    fully masked (the coordinator's bucket padding).  With
    ``guarantee_nonempty`` every row gets at least one unmasked entry in the
    first TCB (models self-loops).
    """
    q = (rng.standard_normal((b, ref.TCB_R, d)) * value_scale).astype(np.float32)
    khat = (rng.standard_normal((b, t * ref.TCB_C, d)) * value_scale).astype(
        np.float32
    )
    vhat = (rng.standard_normal((b, t * ref.TCB_C, d)) * value_scale).astype(
        np.float32
    )
    mask = rng.random((b, t, ref.TCB_R, ref.TCB_C)) < density
    if pad_blocks > 0:
        mask[:, t - pad_blocks :] = False
    if guarantee_nonempty:
        mask[:, 0, :, 0] = True
    bitmap = ref.pack_bitmap_np(mask)
    return q, khat, vhat, bitmap, mask
