"""The AOT pipeline itself: lowering produces parseable HLO text and a
manifest consistent with the executables' shapes."""

import json
import os
import subprocess
import sys

import pytest

HERE = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.fixture(scope="module")
def fast_suite(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts_fast")
    subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out", str(out), "--fast"],
        cwd=HERE,
        check=True,
        capture_output=True,
    )
    return out


def test_manifest_schema(fast_suite):
    with open(fast_suite / "manifest.json") as f:
        man = json.load(f)
    assert man["version"] == 1
    assert man["tcb_r"] == 16 and man["tcb_c"] == 8
    assert man["rw_batch"] >= 1
    assert len(man["executables"]) > 10
    names = {e["name"] for e in man["executables"]}
    assert "fused3s_t4_d32" in names
    for e in man["executables"]:
        # every artifact file exists and is non-trivial HLO text
        path = fast_suite / e["file"]
        assert path.exists(), e["name"]
        text = path.read_text()
        assert "HloModule" in text, e["name"]
        assert e["n_outputs"] >= 1
        for i in e["inputs"]:
            assert i["dtype"] in ("f32", "i32")
            assert all(s > 0 for s in i["shape"])


def test_fused3s_entry_shapes(fast_suite):
    with open(fast_suite / "manifest.json") as f:
        man = json.load(f)
    b = man["rw_batch"]
    e = next(x for x in man["executables"] if x["name"] == "fused3s_t4_d32")
    q, k, v, bm = e["inputs"]
    assert q["shape"] == [b, 16, 32]
    assert k["shape"] == [b, 32, 32]  # t*8 = 32 rows
    assert v["shape"] == [b, 32, 32]
    assert bm["shape"] == [b, 4, 4]
    assert bm["dtype"] == "i32"


def test_hlo_reparses_via_xla_client(fast_suite):
    """The HLO text must round-trip through the XLA parser (what the Rust
    loader does via HloModuleProto::from_text_file)."""
    from jax._src.lib import xla_client as xc

    text = (fast_suite / "fused3s_t4_d32.hlo.txt").read_text()
    # jax's bundled xla can parse hlo text back into a computation.
    comp = xc._xla.hlo_module_from_text(text)
    assert comp is not None
